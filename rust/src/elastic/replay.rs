//! End-to-end dynamic-trace replay: plan → event → replan → resume,
//! measured with the discrete-event simulator on the *current* fleet
//! snapshot at every iteration.
//!
//! Four policies are compared:
//! * **Static** — the incumbent is only *repaired* (forced device
//!   drops), never re-searched; what a scheduler without elasticity
//!   does. Migration pauses are charged for the forced moves.
//! * **Warm** — event-driven replanning: warm-started EA under a
//!   reduced budget with the migration-aware objective. Migration
//!   pauses charged.
//! * **Anytime** — warm replanning *plus* the background anytime
//!   search ([`super::anytime`]): between events, spare controller
//!   cycles (an eval allowance accrued per simulated second) keep
//!   improving an incumbent that is merged — migration-aware — into
//!   the next event's replan. Migration pauses charged.
//! * **Oracle** — an idealized upper bound: full cold-search budget at
//!   every event and free, instant migration.
//!
//! Everything is seeded; a replay is a pure function of
//! `(scenario, spec, wf, job, policy, cfg, seed)` — including the
//! anytime policy, whose background budget is accounted in sim-time.

use super::anytime::AnytimeSearch;
use super::events::{generate_trace, TraceConfig, TraceEvent};
use super::fleet::FleetState;
use super::replan::{plan_to_base, prev_placement, repair_plan, ReplanConfig, Replanner};
use crate::balance::{self, BalanceConfig};
use crate::costmodel::CostModel;
use crate::plan::ExecutionPlan;
use crate::simulator::{simulate_plan, NoiseModel, SimConfig};
use crate::topology::{build_testbed, DeviceTopology, Scenario, TestbedSpec};
use crate::workflow::{JobConfig, RlWorkflow};

/// Replay policy under comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Static,
    Warm,
    Anytime,
    Oracle,
}

impl Policy {
    pub const ALL: [Policy; 4] =
        [Policy::Static, Policy::Warm, Policy::Anytime, Policy::Oracle];

    pub fn name(self) -> &'static str {
        match self {
            Policy::Static => "static",
            Policy::Warm => "warm-replan",
            Policy::Anytime => "anytime",
            Policy::Oracle => "oracle",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Some(Policy::Static),
            "warm" | "warm-replan" | "replan" => Some(Policy::Warm),
            "anytime" | "background" => Some(Policy::Anytime),
            "oracle" => Some(Policy::Oracle),
            _ => None,
        }
    }
}

/// Replay configuration.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Training iterations to replay.
    pub iters: usize,
    pub trace: TraceConfig,
    pub replan: ReplanConfig,
    /// DES iterations averaged per measured point (1 keeps replays
    /// cheap and bit-deterministic).
    pub sim_iters: usize,
    pub noise: NoiseModel,
    /// Apply the heterogeneity load balancer after every (re)plan.
    pub balance: bool,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            iters: 24,
            trace: TraceConfig::default(),
            replan: ReplanConfig::default(),
            sim_iters: 1,
            noise: NoiseModel::default(),
            balance: true,
        }
    }
}

/// One replayed iteration.
#[derive(Debug, Clone, PartialEq)]
pub struct IterRecord {
    pub iter: usize,
    /// Labels of the events that fired before this iteration.
    pub events: Vec<String>,
    pub replanned: bool,
    /// Search evaluations spent at this iteration (0 when no event).
    pub evals: usize,
    /// Per-task cost-cache hits/misses of this iteration's searches —
    /// the event-driven replan plus, under the anytime policy, the
    /// background step (so nonzero on quiet iterations there; 0 on
    /// quiet iterations otherwise). Exact at the default
    /// `ReplanConfig::threads` = 1, approximate under concurrency.
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// One-off migration pause charged at this iteration (seconds).
    pub migration_secs: f64,
    /// Simulated duration of this training iteration (seconds).
    pub iter_secs: f64,
    /// Samples actually processed (0 when the fleet stalled with no
    /// feasible plan).
    pub samples: usize,
    pub active_gpus: usize,
    /// Background anytime-search evaluations spent during this
    /// iteration (sim-time allowance; 0 for non-anytime policies).
    pub anytime_evals: usize,
    /// Anytime incumbent objective after this iteration (∞ for
    /// non-anytime policies or when no incumbent exists). Monotone
    /// non-increasing between events; resets at each barrier.
    pub anytime_cost: f64,
}

/// Full replay outcome for one policy.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayResult {
    pub policy: Policy,
    pub seed: u64,
    pub records: Vec<IterRecord>,
    /// Σ iteration time + Σ migration pauses (seconds).
    pub total_secs: f64,
    /// Samples actually processed (stalled iterations count zero).
    pub samples: usize,
    pub replans: usize,
    pub total_evals: usize,
    /// Background anytime-search evaluations over the whole replay
    /// (0 for non-anytime policies; not counted in `total_evals` —
    /// they are spare sim-time cycles, not event-search budget).
    pub anytime_evals: usize,
    /// Cost-cache telemetry summed over every search in the replay
    /// (initial cold plan and anytime background steps included).
    pub cache_hits: usize,
    pub cache_misses: usize,
}

impl ReplayResult {
    /// Mean per-iteration cost of the replay: iteration time plus
    /// migration pauses, seconds — the CLI's "mean iter (s)" column
    /// (`static ≥ warm ≥ anytime ≥ oracle` is the expected ordering).
    pub fn mean_iter_secs(&self) -> f64 {
        if self.records.is_empty() {
            0.0
        } else {
            self.total_secs / self.records.len() as f64
        }
    }

    /// Fraction of per-task cost lookups served from the cache.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// End-to-end throughput over the whole trace, samples/s.
    pub fn throughput(&self) -> f64 {
        self.samples as f64 / self.total_secs
    }

    /// Throughput restricted to iterations `>= from` (e.g. after the
    /// first preemption), migration pauses included and stalled
    /// iterations contributing time but no samples.
    pub fn throughput_after(&self, from: usize) -> f64 {
        let (mut secs, mut samples) = (0.0f64, 0usize);
        for r in self.records.iter().filter(|r| r.iter >= from) {
            secs += r.iter_secs + r.migration_secs;
            samples += r.samples;
        }
        if secs > 0.0 {
            samples as f64 / secs
        } else {
            0.0
        }
    }
}

/// First iteration at which any event fires (`None` for a quiet trace).
pub fn first_event_iter(trace: &[TraceEvent]) -> Option<usize> {
    trace.iter().map(|e| e.at_iter).min()
}

/// Reseed the background service (when present) on a fresh epoch: the
/// given plan becomes its running plan + incumbent, costed at its pure
/// predicted iteration time — the single convention both the initial
/// cold plan and every event barrier use.
fn reseed_anytime(
    anytime: &mut Option<AnytimeSearch>,
    topo: &DeviceTopology,
    wf: &RlWorkflow,
    job: &JobConfig,
    plan: Option<&ExecutionPlan>,
) {
    if let Some(a) = anytime.as_mut() {
        let cost = plan
            .map(|p| CostModel::new(topo, wf, job).plan_cost(p).iter_time)
            .unwrap_or(f64::INFINITY);
        a.reseed(plan, cost);
    }
}

/// Replay a dynamic trace end-to-end under one policy.
pub fn replay(
    scenario: Scenario,
    spec: &TestbedSpec,
    wf: &RlWorkflow,
    job: &JobConfig,
    policy: Policy,
    cfg: &ReplayConfig,
    seed: u64,
) -> ReplayResult {
    let base = build_testbed(scenario, spec);
    let trace = generate_trace(&base, &cfg.trace, seed);
    let mut fleet = FleetState::new(base);
    let mut replanner = Replanner::new(seed, cfg.replan.clone());
    // The background service exists only under the anytime policy; its
    // allowance is accounted in sim-time, so the replay stays a pure
    // function of its inputs.
    let mut anytime = if policy == Policy::Anytime {
        Some(AnytimeSearch::new(seed ^ 0xA11C_E5EA, cfg.replan.clone()))
    } else {
        None
    };

    // Initial plan on the full fleet (identical across policies: the
    // replanner's episode counter starts equal).
    let (mut topo, mut map) = fleet.snapshot();
    let cold = replanner.cold_plan(&topo, wf, job);
    let mut plan: Option<ExecutionPlan> = cold.plan.map(|p| {
        if cfg.balance {
            balance::apply(&p, wf, &topo, BalanceConfig::default())
        } else {
            p
        }
    });
    let mut incumbent_base = plan.as_ref().map(|p| plan_to_base(p, &map));
    reseed_anytime(&mut anytime, &topo, wf, job, plan.as_ref());

    let mut records = Vec::with_capacity(cfg.iters);
    let mut total_secs = 0.0;
    let mut replans = 0;
    let mut total_evals = cold.evals;
    let mut total_anytime_evals = 0usize;
    let mut cache_hits = cold.cache_hits;
    let mut cache_misses = cold.cache_misses;
    let mut cursor = 0usize;

    for iter in 0..cfg.iters {
        // Fire due events.
        let mut labels = Vec::new();
        while cursor < trace.len() && trace[cursor].at_iter <= iter {
            fleet.apply(&trace[cursor].event);
            labels.push(trace[cursor].event.label());
            cursor += 1;
        }
        let mut migration_secs = 0.0;
        let mut evals = 0;
        let mut iter_hits = 0;
        let mut iter_misses = 0;
        let mut replanned = false;
        if !labels.is_empty() {
            // The anytime incumbent lives in the *pre-event* snapshot
            // space; translate it to base ids with the old map before
            // the snapshot is replaced.
            let anytime_base = anytime
                .as_ref()
                .and_then(|a| a.incumbent().map(|(p, _)| plan_to_base(p, &map)));
            let (t, m) = fleet.snapshot();
            topo = t;
            map = m;
            let b2n = FleetState::base_to_snapshot(&map);
            let mm = cfg.replan.migration;
            let new_plan = match (policy, incumbent_base.as_ref()) {
                (Policy::Static, Some(inc)) => {
                    // Repair only — no search. Migration is charged from
                    // the same surviving-shard placement the replanner
                    // uses (replan::prev_placement).
                    let prev = prev_placement(inc, &b2n);
                    let repaired = repair_plan(inc, wf, job, &topo, &b2n, seed ^ iter as u64);
                    match repaired {
                        Some(p) => {
                            migration_secs = mm.migration_time(&topo, wf, job, &prev, &p);
                            Some(p)
                        }
                        None => {
                            // Cannot even repair: forced cold search —
                            // the "static" system restarts from scratch.
                            let out = replanner.cold_plan(&topo, wf, job);
                            evals += out.evals;
                            iter_hits += out.cache_hits;
                            iter_misses += out.cache_misses;
                            if let Some(p) = &out.plan {
                                migration_secs = mm.migration_time(&topo, wf, job, &prev, p);
                            }
                            out.plan
                        }
                    }
                }
                (Policy::Warm, Some(inc)) => {
                    replanned = true;
                    let out = replanner.replan(&topo, wf, job, inc, &b2n);
                    evals += out.evals;
                    iter_hits += out.cache_hits;
                    iter_misses += out.cache_misses;
                    migration_secs = out.migration_secs;
                    out.plan
                }
                (Policy::Anytime, Some(inc)) => {
                    // Barrier merge: the ordinary warm replan, then the
                    // background incumbent adopted iff strictly better
                    // under the migration-aware objective.
                    replanned = true;
                    let out = replanner.replan_with_anytime(
                        &topo,
                        wf,
                        job,
                        inc,
                        anytime_base.as_ref(),
                        &b2n,
                    );
                    evals += out.evals;
                    iter_hits += out.cache_hits;
                    iter_misses += out.cache_misses;
                    migration_secs = out.migration_secs;
                    out.plan
                }
                (Policy::Oracle, _) | (_, None) => {
                    replanned = true;
                    let out = replanner.cold_plan(&topo, wf, job);
                    evals += out.evals;
                    iter_hits += out.cache_hits;
                    iter_misses += out.cache_misses;
                    // Oracle migrates for free; a policy with no
                    // incumbent has nothing to move.
                    out.plan
                }
            };
            plan = new_plan.map(|p| {
                if cfg.balance {
                    balance::apply(&p, wf, &topo, BalanceConfig::default())
                } else {
                    p
                }
            });
            incumbent_base = plan.as_ref().map(|p| plan_to_base(p, &map));
            if replanned {
                replans += 1;
            }
            // New epoch for the background service: unspent allowance
            // is forfeited while the controller replans.
            reseed_anytime(&mut anytime, &topo, wf, job, plan.as_ref());
        }

        // Measure this iteration on the current snapshot.
        let (iter_secs, iter_samples) = match &plan {
            Some(p) => {
                let sim = SimConfig {
                    iters: cfg.sim_iters.max(1),
                    seed: seed ^ (iter as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    noise: cfg.noise,
                };
                (simulate_plan(&topo, wf, job, p, &sim).iter_time, job.total_samples())
            }
            // No feasible plan: the fleet stalls for a beat (charged as
            // the previous iteration's duration, or a large constant at
            // the start) and processes nothing.
            None => (
                records.last().map(|r: &IterRecord| r.iter_secs).unwrap_or(600.0),
                0,
            ),
        };
        total_secs += iter_secs + migration_secs;

        // Spare controller cycles: credit this iteration's simulated
        // duration to the background allowance and run one anytime
        // step on the current snapshot.
        let mut anytime_evals = 0;
        let mut anytime_cost = f64::INFINITY;
        if let Some(a) = anytime.as_mut() {
            a.accrue(iter_secs);
            let st = a.step(&topo, wf, job);
            anytime_evals = st.evals;
            anytime_cost = st.incumbent_cost;
            iter_hits += st.cache_hits;
            iter_misses += st.cache_misses;
        }
        total_evals += evals;
        total_anytime_evals += anytime_evals;
        cache_hits += iter_hits;
        cache_misses += iter_misses;

        records.push(IterRecord {
            iter,
            events: labels,
            replanned,
            evals,
            cache_hits: iter_hits,
            cache_misses: iter_misses,
            migration_secs,
            iter_secs,
            samples: iter_samples,
            active_gpus: topo.n(),
            anytime_evals,
            anytime_cost,
        });
    }

    ReplayResult {
        policy,
        seed,
        samples: records.iter().map(|r| r.samples).sum(),
        records,
        total_secs,
        replans,
        total_evals,
        anytime_evals: total_anytime_evals,
        cache_hits,
        cache_misses,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::fixtures;
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn tiny_cfg() -> ReplayConfig {
        ReplayConfig {
            iters: 6,
            trace: TraceConfig { horizon: 6, n_events: 2, ..TraceConfig::default() },
            replan: ReplanConfig {
                warm_budget: 40,
                cold_budget: 80,
                seed_mutants: 2,
                ..ReplanConfig::default()
            },
            sim_iters: 1,
            noise: NoiseModel::default(),
            balance: true,
        }
    }

    fn small_spec() -> TestbedSpec {
        fixtures::small_spec()
    }

    #[test]
    fn replay_runs_all_policies() {
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let job = JobConfig::tiny();
        for policy in Policy::ALL {
            let r = replay(
                Scenario::MultiCountry,
                &small_spec(),
                &wf,
                &job,
                policy,
                &tiny_cfg(),
                3,
            );
            assert_eq!(r.records.len(), 6);
            assert!(r.total_secs > 0.0 && r.total_secs.is_finite(), "{policy:?}");
            assert!(r.throughput() > 0.0);
            if policy != Policy::Anytime {
                assert_eq!(r.anytime_evals, 0, "{policy:?} ran background search");
            }
        }
    }

    #[test]
    fn anytime_replay_runs_background_search() {
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let job = JobConfig::tiny();
        let mut cfg = tiny_cfg();
        // Generous allowance so the background search visibly runs even
        // on a tiny trace.
        cfg.replan.anytime.evals_per_sim_sec = 8.0;
        cfg.replan.anytime.max_step_evals = 16;
        let r = replay(Scenario::MultiCountry, &small_spec(), &wf, &job, Policy::Anytime, &cfg, 5);
        assert!(r.anytime_evals > 0, "no background evals spent");
        assert_eq!(
            r.anytime_evals,
            r.records.iter().map(|x| x.anytime_evals).sum::<usize>()
        );
        for rec in &r.records {
            assert!(rec.anytime_evals <= cfg.replan.anytime.max_step_evals);
        }
    }

    #[test]
    fn replay_is_deterministic() {
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let job = JobConfig::tiny();
        let a = replay(
            Scenario::MultiRegionHybrid,
            &small_spec(),
            &wf,
            &job,
            Policy::Warm,
            &tiny_cfg(),
            9,
        );
        let b = replay(
            Scenario::MultiRegionHybrid,
            &small_spec(),
            &wf,
            &job,
            Policy::Warm,
            &tiny_cfg(),
            9,
        );
        assert_eq!(a, b);
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("nope"), None);
    }
}
