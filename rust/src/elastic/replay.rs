//! End-to-end dynamic-trace replay: plan → event → replan → resume,
//! measured with the discrete-event simulator on the *current* fleet
//! snapshot at every iteration.
//!
//! Five policies are compared (in the fixed [`Policy::ALL`] order):
//! * **Static** — the incumbent is only *repaired* (forced device
//!   drops), never re-searched; what a scheduler without elasticity
//!   does. Migration pauses are charged for the forced moves.
//! * **Warm** — event-driven replanning: warm-started EA under a
//!   reduced budget with the migration-aware objective. Migration
//!   pauses charged.
//! * **Anytime** — warm replanning *plus* the background anytime
//!   search ([`super::anytime`]): between events, spare controller
//!   cycles (an eval allowance accrued per simulated second) keep
//!   improving an incumbent that is merged — migration-aware — into
//!   the next event's replan. Migration pauses charged.
//! * **Preempt** — the anytime policy *plus predictive preemption*:
//!   when an upcoming machine-loss event carries advance notice
//!   ([`super::events::TraceEvent::notice_secs`]) that covers the
//!   estimated time until it fires, the background allowance is split
//!   between the primary incumbent and a second incumbent searched
//!   against the *post-event fleet hypothesis*
//!   ([`super::fleet::FleetState::apply_hypothetical`]). At the
//!   barrier where the predicted event actually fires, the pre-warmed
//!   hypothesis plan joins the merge and is adopted iff strictly
//!   better — so the policy plans *through* forecast churn instead of
//!   merely reacting to it, and on zero-notice traces it degenerates
//!   bit-identically to the anytime policy.
//! * **Oracle** — an idealized upper bound: full cold-search budget at
//!   every event and free, instant migration.
//!
//! Everything is seeded; a replay is a pure function of
//! `(scenario, spec, wf, job, policy, cfg, seed)` — including the
//! anytime/preempt policies, whose background budget is accounted in
//! sim-time.
//!
//! # Failure & recovery
//!
//! With [`ReplayConfig::recovery`] enabled the replay additionally
//! prices (all in sim-time, so determinism is untouched):
//!
//! * **checkpoint writes** at the configured (or searched, see
//!   [`ReplayConfig::ckpt_search`]) cadence
//!   ([`crate::costmodel::RecoveryModel::ckpt_write_secs`]);
//! * **rollback/rework** on *unnoticed* machine losses and on task
//!   failures that exhaust their retry budget — the productive sim-time
//!   since the last completed checkpoint is re-run
//!   ([`crate::costmodel::RecoveryState::rollback`]); noticed losses
//!   charge no rework, which is precisely the priced value of notice;
//! * **retry stalls** for transient faults (NIC bursts,
//!   checkpoint-store outages, task failures) under a deterministic
//!   bounded linear backoff.
//!
//! A fleet snapshot with **zero machines** (every machine lost) no
//! longer errors: the replay enters a *degraded* state — the incumbent
//! is retained in base-id space, iterations stall at the usual
//! no-feasible-plan price, and planning resumes at the next join
//! barrier ([`IterRecord::degraded`] flags such iterations). With
//! recovery disabled (the default) every new charge is exactly `0.0`
//! and the replay is bit-identical to the pre-recovery driver.

use super::anytime::AnytimeSearch;
use super::events::{generate_trace, ClusterEvent, TraceConfig, TraceEvent};
use super::fleet::FleetState;
use super::recovery::{plan_with_ckpt_interval, CkptSearchConfig};
use super::replan::{plan_to_base, prev_placement, repair_plan, ReplanConfig, Replanner};
use crate::balance::{self, BalanceConfig};
use crate::costmodel::{CostModel, RecoveryModel, RecoveryState};
use crate::plan::ExecutionPlan;
use crate::simulator::{simulate_plan, NoiseModel, SimConfig};
use crate::topology::{build_testbed, DeviceTopology, Scenario, TestbedSpec};
use crate::workflow::{JobConfig, RlWorkflow};

/// Replay policy under comparison (see the module docs for semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Repair-only incumbent; no re-search after events.
    Static,
    /// Event-driven warm replanning.
    Warm,
    /// Warm replanning + background anytime search between events.
    Anytime,
    /// Anytime + predictive preemption on noticed machine losses.
    Preempt,
    /// Full-budget re-search with free, instant migration (upper bound).
    Oracle,
}

impl Policy {
    /// Every policy, in the **fixed documented order** the CLI's
    /// `--policy all` prints and `benches/fig11_elastic.rs` records:
    /// `static`, `warm-replan`, `anytime`, `preempt`, `oracle` —
    /// reactive sophistication ascending, the oracle bound last.
    pub const ALL: [Policy; 5] = [
        Policy::Static,
        Policy::Warm,
        Policy::Anytime,
        Policy::Preempt,
        Policy::Oracle,
    ];

    /// Stable display name (also accepted by [`Policy::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Policy::Static => "static",
            Policy::Warm => "warm-replan",
            Policy::Anytime => "anytime",
            Policy::Preempt => "preempt",
            Policy::Oracle => "oracle",
        }
    }

    /// Parse a CLI policy name (case-insensitive, with aliases).
    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Some(Policy::Static),
            "warm" | "warm-replan" | "replan" => Some(Policy::Warm),
            "anytime" | "background" => Some(Policy::Anytime),
            "preempt" | "predictive" | "notice" => Some(Policy::Preempt),
            "oracle" => Some(Policy::Oracle),
            _ => None,
        }
    }

    /// Whether the policy owns a background [`AnytimeSearch`] service.
    pub fn runs_background(self) -> bool {
        matches!(self, Policy::Anytime | Policy::Preempt)
    }
}

/// Replay configuration.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Training iterations to replay.
    pub iters: usize,
    /// Trace-generation knobs (horizon, event count, notice override).
    pub trace: TraceConfig,
    /// Replanning knobs shared by every policy (budgets, migration
    /// model, anytime allowance, worker threads).
    pub replan: ReplanConfig,
    /// DES iterations averaged per measured point (1 keeps replays
    /// cheap and bit-deterministic).
    pub sim_iters: usize,
    /// Simulator noise model applied to each measured iteration.
    pub noise: NoiseModel,
    /// Apply the heterogeneity load balancer after every (re)plan.
    pub balance: bool,
    /// Failure-and-recovery pricing (checkpoint cadence, rollback,
    /// retry/backoff). Disabled by default, which keeps the replay
    /// bit-identical to the pre-recovery driver.
    pub recovery: RecoveryModel,
    /// When set (and `recovery` is enabled), the initial cold search
    /// treats the checkpoint interval as a searched plan dimension
    /// ([`super::recovery::plan_with_ckpt_interval`]); the winning
    /// interval replaces `recovery.ckpt_interval_secs` for the replay.
    pub ckpt_search: Option<CkptSearchConfig>,
    /// Optional seeded same-timestamp tie shuffle for every DES
    /// measurement in the replay (`None` = FIFO order, byte-identical
    /// to the pre-shuffle driver). Replay metrics are invariant under
    /// any seed — the property `tests/prop_interleave.rs` fuzzes.
    pub shuffle: Option<crate::simulator::ShuffleConfig>,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            iters: 24,
            trace: TraceConfig::default(),
            replan: ReplanConfig::default(),
            sim_iters: 1,
            noise: NoiseModel::default(),
            balance: true,
            recovery: RecoveryModel::default(),
            ckpt_search: None,
            shuffle: None,
        }
    }
}

/// One replayed iteration.
#[derive(Debug, Clone, PartialEq)]
pub struct IterRecord {
    /// Iteration index within the replay (`0..ReplayConfig::iters`).
    pub iter: usize,
    /// Labels of the events that fired before this iteration.
    pub events: Vec<String>,
    /// Whether a (warm or cold) re-search ran at this iteration.
    pub replanned: bool,
    /// Search evaluations spent at this iteration (0 when no event).
    pub evals: usize,
    /// Per-task cost-cache hits of this iteration's searches — the
    /// event-driven replan plus, under the background policies, the
    /// anytime step (so nonzero on quiet iterations there; 0 on quiet
    /// iterations otherwise). Exact at the default
    /// `ReplanConfig::threads` = 1, approximate under concurrency.
    pub cache_hits: usize,
    /// Per-task cost-cache misses (same scope as `cache_hits`).
    pub cache_misses: usize,
    /// One-off migration pause charged at this iteration (seconds).
    pub migration_secs: f64,
    /// Simulated duration of this training iteration (seconds).
    pub iter_secs: f64,
    /// Samples actually processed (0 when the fleet stalled with no
    /// feasible plan).
    pub samples: usize,
    /// GPUs in the active fleet snapshot at this iteration.
    pub active_gpus: usize,
    /// Background anytime-search evaluations spent on the *primary*
    /// incumbent during this iteration (sim-time allowance; 0 for
    /// non-background policies).
    pub anytime_evals: usize,
    /// Background evaluations spent on the *post-event hypothesis*
    /// incumbent during this iteration (predictive preemption; nonzero
    /// only under `Policy::Preempt` while a noticed machine loss is
    /// pending). `anytime_evals + hypothesis_evals` stays within the
    /// sim-time allowance and the per-step cap.
    pub hypothesis_evals: usize,
    /// Anytime incumbent objective after this iteration (∞ for
    /// non-background policies or when no incumbent exists). Monotone
    /// non-increasing between events; resets at each barrier.
    pub anytime_cost: f64,
    /// Retry/backoff stall charged for transient faults that fired
    /// before this iteration (0.0 with recovery disabled; bounded by
    /// faults × [`crate::costmodel::RecoveryModel::max_stall_secs`]).
    pub retry_stall_secs: f64,
    /// Rollback rework charged at this iteration: productive sim-time
    /// since the last completed checkpoint, re-run because an unnoticed
    /// machine loss (or a retry-exhausted task failure) fired (0.0 with
    /// recovery disabled).
    pub rework_secs: f64,
    /// Checkpoint-write overhead charged during this iteration (0.0
    /// with recovery disabled, checkpointing off, or the store down).
    pub ckpt_secs: f64,
    /// Whether the replay was *degraded* at this iteration: no feasible
    /// plan exists (e.g. every machine lost), the fleet stalls, and the
    /// retained incumbent resumes at the next join barrier.
    pub degraded: bool,
}

/// Full replay outcome for one policy.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayResult {
    /// The policy this replay ran under.
    pub policy: Policy,
    /// Seed the trace, searches and simulator all derive from.
    pub seed: u64,
    /// Per-iteration telemetry, one record per replayed iteration.
    pub records: Vec<IterRecord>,
    /// Σ iteration time + Σ migration pauses (seconds).
    pub total_secs: f64,
    /// Samples actually processed (stalled iterations count zero).
    pub samples: usize,
    /// Event barriers at which a re-search (warm or cold) ran.
    pub replans: usize,
    /// Event-search evaluations over the whole replay (initial cold
    /// plan + every barrier episode; background evals excluded).
    pub total_evals: usize,
    /// Background anytime-search evaluations spent on the primary
    /// incumbent over the whole replay (0 for non-background policies;
    /// not counted in `total_evals` — they are spare sim-time cycles,
    /// not event-search budget).
    pub anytime_evals: usize,
    /// Background evaluations spent on the post-event hypothesis
    /// incumbent over the whole replay (predictive preemption; 0 for
    /// every policy but `Policy::Preempt`).
    pub hypothesis_evals: usize,
    /// Cost-cache hits summed over every search in the replay (initial
    /// cold plan and background steps included).
    pub cache_hits: usize,
    /// Cost-cache misses (same scope as `cache_hits`).
    pub cache_misses: usize,
    /// Σ [`IterRecord::retry_stall_secs`] (0.0 with recovery disabled).
    pub retry_stall_secs: f64,
    /// Σ [`IterRecord::rework_secs`] (0.0 with recovery disabled).
    pub rework_secs: f64,
    /// Σ [`IterRecord::ckpt_secs`] (0.0 with recovery disabled).
    pub ckpt_secs: f64,
    /// Checkpoints completed over the replay.
    pub ckpts: usize,
    /// Iterations spent degraded (no feasible plan; see
    /// [`IterRecord::degraded`]).
    pub degraded_iters: usize,
    /// Checkpoint interval in effect: the searched winner under
    /// [`ReplayConfig::ckpt_search`], otherwise the configured cadence
    /// (0.0 when recovery is disabled).
    pub ckpt_interval_secs: f64,
}

impl ReplayResult {
    /// Mean per-iteration cost of the replay: iteration time plus
    /// migration pauses, seconds — the CLI's "mean iter (s)" column
    /// (`static ≥ warm ≥ anytime ≥ oracle` is the expected ordering).
    pub fn mean_iter_secs(&self) -> f64 {
        if self.records.is_empty() {
            0.0
        } else {
            self.total_secs / self.records.len() as f64
        }
    }

    /// Fraction of per-task cost lookups served from the cache.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// End-to-end throughput over the whole trace, samples/s.
    pub fn throughput(&self) -> f64 {
        self.samples as f64 / self.total_secs
    }

    /// Throughput restricted to iterations `>= from` (e.g. after the
    /// first preemption), migration pauses included and stalled
    /// iterations contributing time but no samples.
    pub fn throughput_after(&self, from: usize) -> f64 {
        let (mut secs, mut samples) = (0.0f64, 0usize);
        for r in self.records.iter().filter(|r| r.iter >= from) {
            secs += r.iter_secs + r.migration_secs;
            samples += r.samples;
        }
        if secs > 0.0 {
            samples as f64 / secs
        } else {
            0.0
        }
    }
}

/// First iteration at which any event fires (`None` for a quiet trace).
pub fn first_event_iter(trace: &[TraceEvent]) -> Option<usize> {
    trace.iter().map(|e| e.at_iter).min()
}

/// Index into `trace` of the next unfired machine-loss event whose
/// advance notice covers the estimated time until it fires. With the
/// event landing before iteration `at_iter` and the replay having just
/// measured iteration `iter` at `iter_secs` simulated seconds,
/// `at_iter - (iter + 1)` full iterations remain — each estimated at
/// `iter_secs`. Only the *nearest* upcoming loss is ever predicted
/// (forecasting past it would compound speculation); `None` when that
/// loss carries no notice or its window has not opened yet.
fn next_noticed_loss(
    trace: &[TraceEvent],
    cursor: usize,
    iter: usize,
    iter_secs: f64,
) -> Option<usize> {
    let (idx, ev) = trace
        .iter()
        .enumerate()
        .skip(cursor)
        .find(|(_, e)| e.is_machine_loss())?;
    let notice = ev.notice_secs?;
    let remaining = ev.at_iter.saturating_sub(iter + 1) as f64 * iter_secs.max(0.0);
    (remaining <= notice).then_some(idx)
}

/// Reseed the background service (when present) on a fresh epoch: the
/// given plan becomes its running plan + incumbent, costed at its pure
/// predicted iteration time — the single convention both the initial
/// cold plan and every event barrier use.
fn reseed_anytime(
    anytime: &mut Option<AnytimeSearch>,
    topo: &DeviceTopology,
    wf: &RlWorkflow,
    job: &JobConfig,
    plan: Option<&ExecutionPlan>,
) {
    if let Some(a) = anytime.as_mut() {
        let cost = plan
            .map(|p| CostModel::new(topo, wf, job).plan_cost(p).iter_time)
            .unwrap_or(f64::INFINITY);
        a.reseed(plan, cost);
    }
}

/// Replay a dynamic trace end-to-end under one policy.
pub fn replay(
    scenario: Scenario,
    spec: &TestbedSpec,
    wf: &RlWorkflow,
    job: &JobConfig,
    policy: Policy,
    cfg: &ReplayConfig,
    seed: u64,
) -> ReplayResult {
    let base = build_testbed(scenario, spec);
    let trace = generate_trace(&base, &cfg.trace, seed);
    replay_with_trace(base, trace, wf, job, policy, cfg, seed)
}

/// [`replay`] with an injected base topology and event trace instead of
/// the seeded generator — the entry point for adversarial traces the
/// generator would rarely draw (e.g. every machine lost at once, which
/// must degrade gracefully rather than panic). `cfg.trace` is ignored;
/// everything else behaves exactly as in [`replay`], and
/// `replay(scenario, spec, ...)` is by definition
/// `replay_with_trace(build_testbed(..), generate_trace(..), ...)`.
pub fn replay_with_trace(
    base: DeviceTopology,
    trace: Vec<TraceEvent>,
    wf: &RlWorkflow,
    job: &JobConfig,
    policy: Policy,
    cfg: &ReplayConfig,
    seed: u64,
) -> ReplayResult {
    let mut fleet = FleetState::new(base);
    let mut replanner = Replanner::new(seed, cfg.replan.clone());
    // Recovery pricing: local copy so a searched checkpoint interval
    // can replace the configured cadence without touching the config.
    let mut recovery = cfg.recovery;
    let mut recov_state = RecoveryState::default();
    // The background service exists only under the anytime/preempt
    // policies; its allowance is accounted in sim-time, so the replay
    // stays a pure function of its inputs. Both policies share the
    // service seed — on a zero-notice trace the preempt policy is
    // bit-identical to the anytime policy.
    let mut anytime = if policy.runs_background() {
        Some(AnytimeSearch::new(seed ^ 0xA11C_E5EA, cfg.replan.clone()))
    } else {
        None
    };
    // The predicted-event state of the preempt policy: the hypothetical
    // post-event snapshot (topology + snapshot→base map) and the trace
    // index of the noticed loss it anticipates.
    let mut hypo: Option<(DeviceTopology, Vec<usize>, usize)> = None;

    // Initial plan on the full fleet (identical across policies: the
    // replanner's episode counter starts equal). With a checkpoint
    // search configured the cold episode additionally picks the
    // cadence; without one the episode is the plain cold search,
    // bit-identical to the pre-recovery driver.
    let (mut topo, mut map) = fleet.snapshot();
    let cold = match &cfg.ckpt_search {
        Some(cs) if recovery.enabled => {
            let (out, interval) = plan_with_ckpt_interval(
                &mut replanner,
                &topo,
                wf,
                job,
                &trace,
                &recovery,
                cs,
                cfg.iters,
            );
            recovery.ckpt_interval_secs = interval;
            out
        }
        _ => replanner.cold_plan(&topo, wf, job),
    };
    let mut plan: Option<ExecutionPlan> = cold.plan.map(|p| {
        if cfg.balance {
            balance::apply(&p, wf, &topo, BalanceConfig::default())
        } else {
            p
        }
    });
    let mut incumbent_base = plan.as_ref().map(|p| plan_to_base(p, &map));
    reseed_anytime(&mut anytime, &topo, wf, job, plan.as_ref());

    let mut records = Vec::with_capacity(cfg.iters);
    let mut total_secs = 0.0;
    let mut replans = 0;
    let mut total_evals = cold.evals;
    let mut total_anytime_evals = 0usize;
    let mut total_hypothesis_evals = 0usize;
    let mut cache_hits = cold.cache_hits;
    let mut cache_misses = cold.cache_misses;
    let mut cursor = 0usize;
    let mut total_stall = 0.0f64;
    let mut total_rework = 0.0f64;
    let mut total_ckpt = 0.0f64;
    let mut degraded_iters = 0usize;

    for iter in 0..cfg.iters {
        // Fire due events.
        let fired_from = cursor;
        let mut labels = Vec::new();
        while cursor < trace.len() && trace[cursor].at_iter <= iter {
            fleet.apply(&trace[cursor].event);
            labels.push(trace[cursor].label());
            cursor += 1;
        }
        // Recovery pricing for the events that just fired: transient
        // faults stall for their bounded retry/backoff; unnoticed
        // machine losses — and task failures whose drawn attempts
        // exhaust the retry budget — roll the job back to the last
        // completed checkpoint (the rework is re-run productive
        // sim-time). Noticed losses charge nothing here: the notice
        // window is what lets state drain before the machine vanishes.
        let mut retry_stall_secs = 0.0f64;
        let mut rework_secs = 0.0f64;
        if recovery.enabled {
            for ev in &trace[fired_from..cursor] {
                if let Some(attempts) = ev.event.attempts() {
                    let (stall, recovered) = recovery.retry_stall(attempts);
                    retry_stall_secs += stall;
                    if !recovered && matches!(ev.event, ClusterEvent::TaskFailure { .. }) {
                        rework_secs += recov_state.rollback();
                    }
                }
                if ev.is_machine_loss() && ev.notice_secs.is_none() {
                    rework_secs += recov_state.rollback();
                }
            }
        }
        let mut migration_secs = 0.0;
        let mut evals = 0;
        let mut iter_hits = 0;
        let mut iter_misses = 0;
        let mut replanned = false;
        if !labels.is_empty() {
            // The anytime incumbent lives in the *pre-event* snapshot
            // space; translate it to base ids with the old map before
            // the snapshot is replaced.
            let anytime_base = anytime
                .as_ref()
                .and_then(|a| a.incumbent().map(|(p, _)| plan_to_base(p, &map)));
            // The hypothesis incumbent lives in the *hypothetical
            // post-event* snapshot space; it joins the barrier merge
            // only when the event it predicted is among those that just
            // fired (otherwise it was shaped for a fleet that never
            // materialized and is discarded).
            let hypothesis_base = match (&anytime, &hypo) {
                (Some(a), Some((_, hyp_map, idx)))
                    if (fired_from..cursor).contains(idx) =>
                {
                    a.hypothesis().map(|(p, _)| plan_to_base(p, hyp_map))
                }
                _ => None,
            };
            let (t, m) = fleet.snapshot();
            topo = t;
            map = m;
            let b2n = FleetState::base_to_snapshot(&map);
            let mm = cfg.replan.migration;
            let new_plan = match (policy, incumbent_base.as_ref()) {
                (Policy::Static, Some(inc)) => {
                    // Repair only — no search. Migration is charged from
                    // the same surviving-shard placement the replanner
                    // uses (replan::prev_placement).
                    let prev = prev_placement(inc, &b2n);
                    let repaired = repair_plan(inc, wf, job, &topo, &b2n, seed ^ iter as u64);
                    match repaired {
                        Some(p) => {
                            migration_secs = mm.migration_time(&topo, wf, job, &prev, &p);
                            Some(p)
                        }
                        None => {
                            // Cannot even repair: forced cold search —
                            // the "static" system restarts from scratch.
                            let out = replanner.cold_plan(&topo, wf, job);
                            evals += out.evals;
                            iter_hits += out.cache_hits;
                            iter_misses += out.cache_misses;
                            if let Some(p) = &out.plan {
                                migration_secs = mm.migration_time(&topo, wf, job, &prev, p);
                            }
                            out.plan
                        }
                    }
                }
                (Policy::Warm, Some(inc)) => {
                    replanned = true;
                    let out = replanner.replan(&topo, wf, job, inc, &b2n);
                    evals += out.evals;
                    iter_hits += out.cache_hits;
                    iter_misses += out.cache_misses;
                    migration_secs = out.migration_secs;
                    out.plan
                }
                (Policy::Anytime | Policy::Preempt, Some(inc)) => {
                    // Barrier merge: the ordinary warm replan, then the
                    // background incumbent — and, under the preempt
                    // policy, the pre-warmed hypothesis plan when its
                    // predicted event actually fired — adopted iff
                    // strictly better under the migration-aware
                    // objective.
                    replanned = true;
                    let out = replanner.replan_with_anytime(
                        &topo,
                        wf,
                        job,
                        inc,
                        anytime_base.as_ref(),
                        hypothesis_base.as_ref(),
                        &b2n,
                    );
                    evals += out.evals;
                    iter_hits += out.cache_hits;
                    iter_misses += out.cache_misses;
                    migration_secs = out.migration_secs;
                    out.plan
                }
                (Policy::Oracle, _) | (_, None) => {
                    replanned = true;
                    let out = replanner.cold_plan(&topo, wf, job);
                    evals += out.evals;
                    iter_hits += out.cache_hits;
                    iter_misses += out.cache_misses;
                    // Oracle migrates for free; a policy with no
                    // incumbent has nothing to move.
                    out.plan
                }
            };
            plan = new_plan.map(|p| {
                if cfg.balance {
                    balance::apply(&p, wf, &topo, BalanceConfig::default())
                } else {
                    p
                }
            });
            // Graceful degradation: when the barrier produced no plan
            // (e.g. zero machines survive — the guarded cold search
            // returns `None` instead of erroring), *retain* the
            // incumbent in base-id space. The fleet stalls at the
            // degraded price below and planning resumes from the
            // retained incumbent at the next join barrier, instead of
            // restarting cold from nothing.
            if let Some(p) = plan.as_ref() {
                incumbent_base = Some(plan_to_base(p, &map));
            }
            if replanned {
                replans += 1;
            }
            // New epoch for the background service: unspent allowance
            // is forfeited while the controller replans, and any
            // hypothesis is stale (the fleet just changed) — the notice
            // scan below re-primes it against the new fleet if the
            // predicted event is still upcoming.
            reseed_anytime(&mut anytime, &topo, wf, job, plan.as_ref());
            hypo = None;
        }

        // Measure this iteration on the current snapshot.
        let (iter_secs, iter_samples) = match &plan {
            Some(p) => {
                let sim = SimConfig {
                    iters: cfg.sim_iters.max(1),
                    seed: seed ^ (iter as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    noise: cfg.noise,
                    shuffle: cfg.shuffle,
                };
                (simulate_plan(&topo, wf, job, p, &sim).iter_time, job.total_samples())
            }
            // No feasible plan: the fleet stalls for a beat (charged as
            // the previous iteration's duration, or a large constant at
            // the start) and processes nothing.
            None => (
                records.last().map(|r: &IterRecord| r.iter_secs).unwrap_or(600.0),
                0,
            ),
        };
        // Checkpoint cadence: only productive iterations advance the
        // cadence clock (a degraded stall makes no progress worth
        // persisting), and an outage of the checkpoint store freezes
        // the stable point — lengthening the rollback exposure, which
        // is exactly the risk an outage creates. With recovery disabled
        // every charge below is exactly 0.0, keeping the sum
        // bit-identical to the pre-recovery driver.
        let mut ckpt_secs = 0.0f64;
        if recovery.enabled {
            if let Some(p) = &plan {
                let write = recovery.ckpt_write_secs(&cfg.replan.migration, wf, job, p);
                ckpt_secs = recov_state.advance(
                    iter_secs,
                    write,
                    fleet.store_up(),
                    recovery.ckpt_interval_secs,
                );
            }
        }
        let degraded = plan.is_none();
        if degraded {
            degraded_iters += 1;
        }
        total_secs += iter_secs + migration_secs + retry_stall_secs + rework_secs + ckpt_secs;
        total_stall += retry_stall_secs;
        total_rework += rework_secs;
        total_ckpt += ckpt_secs;

        // Predictive preemption: when the nearest upcoming machine
        // loss carries notice that covers the estimated time until it
        // fires, snapshot the post-event fleet hypothesis and prime the
        // second incumbent against it. Everything here is derived from
        // replay state (trace, fleet, measured sim-time), never
        // wall-clock, so the policy keeps the determinism contract.
        if policy == Policy::Preempt {
            // The notice latches: once received it is never retracted
            // (a real spot warning does not un-happen), so a noisy
            // iteration measurement cannot re-close the window and
            // discard the evolved hypothesis. Within an epoch the
            // nearest unfired loss is fixed; barriers reset the latch.
            if hypo.is_none() {
                if let Some(idx) = next_noticed_loss(&trace, cursor, iter, iter_secs) {
                    let hyp_fleet = fleet.apply_hypothetical(&trace[idx].event);
                    let (ht, hm) = hyp_fleet.snapshot();
                    // An empty hypothetical fleet (the predicted loss
                    // takes the last machine) has nothing to search —
                    // skip priming instead of handing the background
                    // service a zero-device topology.
                    if ht.n() > 0 {
                        hypo = Some((ht, hm, idx));
                    }
                }
            }
            if let (Some(a), Some((ht, hm, idx))) = (anytime.as_mut(), hypo.as_ref()) {
                if a.hypothesis_key() != Some(*idx as u64) {
                    let hb2n = FleetState::base_to_snapshot(hm);
                    let mm = cfg.replan.migration;
                    let horizon = cfg.replan.horizon_iters.max(1.0);
                    let prev = incumbent_base
                        .as_ref()
                        .map(|inc| prev_placement(inc, &hb2n))
                        .unwrap_or_default();
                    // Seed: the running plan repaired into the
                    // hypothetical snapshot, costed migration-aware
                    // from its own surviving placement there.
                    let seed_plan = incumbent_base.as_ref().and_then(|inc| {
                        repair_plan(
                            inc,
                            wf,
                            job,
                            ht,
                            &hb2n,
                            seed ^ (*idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                        )
                    });
                    let objective = seed_plan
                        .as_ref()
                        .map(|p| {
                            CostModel::new(ht, wf, job).plan_cost(p).iter_time
                                + mm.migration_time(ht, wf, job, &prev, p) / horizon
                        })
                        .unwrap_or(f64::INFINITY);
                    a.prime_hypothesis(*idx as u64, seed_plan.as_ref(), objective, prev);
                }
            }
        }

        // Spare controller cycles: credit this iteration's simulated
        // duration to the background allowance and run one anytime
        // step on the current snapshot (split with the hypothesis
        // snapshot when predictive preemption has one pending).
        let mut anytime_evals = 0;
        let mut hypothesis_evals = 0;
        let mut anytime_cost = f64::INFINITY;
        if let Some(a) = anytime.as_mut() {
            a.accrue(iter_secs);
            let st = a.step(&topo, wf, job, hypo.as_ref().map(|(t, _, _)| t));
            anytime_evals = st.evals;
            hypothesis_evals = st.hypothesis_evals;
            anytime_cost = st.incumbent_cost;
            iter_hits += st.cache_hits;
            iter_misses += st.cache_misses;
        }
        total_evals += evals;
        total_anytime_evals += anytime_evals;
        total_hypothesis_evals += hypothesis_evals;
        cache_hits += iter_hits;
        cache_misses += iter_misses;

        records.push(IterRecord {
            iter,
            events: labels,
            replanned,
            evals,
            cache_hits: iter_hits,
            cache_misses: iter_misses,
            migration_secs,
            iter_secs,
            samples: iter_samples,
            active_gpus: topo.n(),
            anytime_evals,
            hypothesis_evals,
            anytime_cost,
            retry_stall_secs,
            rework_secs,
            ckpt_secs,
            degraded,
        });
    }

    ReplayResult {
        policy,
        seed,
        samples: records.iter().map(|r| r.samples).sum(),
        records,
        total_secs,
        replans,
        total_evals,
        anytime_evals: total_anytime_evals,
        hypothesis_evals: total_hypothesis_evals,
        cache_hits,
        cache_misses,
        retry_stall_secs: total_stall,
        rework_secs: total_rework,
        ckpt_secs: total_ckpt,
        ckpts: recov_state.ckpts,
        degraded_iters,
        ckpt_interval_secs: if recovery.enabled { recovery.ckpt_interval_secs } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::fixtures;
    use crate::workflow::{Algo, Mode, ModelSpec};

    fn tiny_cfg() -> ReplayConfig {
        ReplayConfig {
            iters: 6,
            trace: TraceConfig { horizon: 6, n_events: 2, ..TraceConfig::default() },
            replan: ReplanConfig {
                warm_budget: 40,
                cold_budget: 80,
                seed_mutants: 2,
                ..ReplanConfig::default()
            },
            ..ReplayConfig::default()
        }
    }

    fn small_spec() -> TestbedSpec {
        fixtures::small_spec()
    }

    #[test]
    fn replay_runs_all_policies() {
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let job = JobConfig::tiny();
        for policy in Policy::ALL {
            let r = replay(
                Scenario::MultiCountry,
                &small_spec(),
                &wf,
                &job,
                policy,
                &tiny_cfg(),
                3,
            );
            assert_eq!(r.records.len(), 6);
            assert!(r.total_secs > 0.0 && r.total_secs.is_finite(), "{policy:?}");
            assert!(r.throughput() > 0.0);
            if !policy.runs_background() {
                assert_eq!(r.anytime_evals, 0, "{policy:?} ran background search");
            }
            if policy != Policy::Preempt {
                assert_eq!(r.hypothesis_evals, 0, "{policy:?} ran hypothesis search");
            }
        }
    }

    #[test]
    fn anytime_replay_runs_background_search() {
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let job = JobConfig::tiny();
        let mut cfg = tiny_cfg();
        // Generous allowance so the background search visibly runs even
        // on a tiny trace.
        cfg.replan.anytime.evals_per_sim_sec = 8.0;
        cfg.replan.anytime.max_step_evals = 16;
        let r = replay(Scenario::MultiCountry, &small_spec(), &wf, &job, Policy::Anytime, &cfg, 5);
        assert!(r.anytime_evals > 0, "no background evals spent");
        assert_eq!(
            r.anytime_evals,
            r.records.iter().map(|x| x.anytime_evals).sum::<usize>()
        );
        for rec in &r.records {
            assert!(rec.anytime_evals <= cfg.replan.anytime.max_step_evals);
        }
    }

    #[test]
    fn replay_is_deterministic() {
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let job = JobConfig::tiny();
        let a = replay(
            Scenario::MultiRegionHybrid,
            &small_spec(),
            &wf,
            &job,
            Policy::Warm,
            &tiny_cfg(),
            9,
        );
        let b = replay(
            Scenario::MultiRegionHybrid,
            &small_spec(),
            &wf,
            &job,
            Policy::Warm,
            &tiny_cfg(),
            9,
        );
        assert_eq!(a, b);
    }

    #[test]
    fn inert_recovery_is_bit_identical() {
        // Loss-free trace + checkpointing disabled: recovery *enabled*
        // must reproduce the recovery-disabled replay bit-for-bit.
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let job = JobConfig::tiny();
        let mut quiet = tiny_cfg();
        quiet.trace.n_events = 0;
        let mut inert = quiet.clone();
        inert.recovery = crate::costmodel::RecoveryModel::with_interval(0.0);
        for policy in [Policy::Warm, Policy::Preempt] {
            let plain =
                replay(Scenario::MultiCountry, &small_spec(), &wf, &job, policy, &quiet, 11);
            let rec =
                replay(Scenario::MultiCountry, &small_spec(), &wf, &job, policy, &inert, 11);
            assert_eq!(plain.total_secs.to_bits(), rec.total_secs.to_bits(), "{policy:?}");
            assert_eq!(plain.records, rec.records, "{policy:?}");
            assert_eq!(rec.rework_secs, 0.0);
            assert_eq!(rec.retry_stall_secs, 0.0);
            assert_eq!(rec.ckpts, 0);
        }
    }

    #[test]
    fn faults_charge_exactly_their_recovery_time() {
        // Same faulty trace with and without recovery pricing: events,
        // plans and measurements are identical, so the enabled run's
        // extra time must be exactly its stall + rework + checkpoint
        // telemetry — and some stall must actually be charged (every
        // generated fault carries attempts ≥ 1).
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let job = JobConfig::tiny();
        let mut cfg = tiny_cfg();
        cfg.trace.fault_events = 3;
        let mut priced = cfg.clone();
        priced.recovery = crate::costmodel::RecoveryModel::with_interval(120.0);
        let free = replay(Scenario::MultiCountry, &small_spec(), &wf, &job, Policy::Warm, &cfg, 2);
        let paid =
            replay(Scenario::MultiCountry, &small_spec(), &wf, &job, Policy::Warm, &priced, 2);
        assert!(paid.retry_stall_secs > 0.0, "no fault stall charged");
        let extra = paid.retry_stall_secs + paid.rework_secs + paid.ckpt_secs;
        assert!(
            (paid.total_secs - free.total_secs - extra).abs() < 1e-9 * paid.total_secs.max(1.0),
            "recovery charge mismatch: {} vs {} + {extra}",
            paid.total_secs,
            free.total_secs
        );
        assert_eq!(
            paid.retry_stall_secs,
            paid.records.iter().map(|r| r.retry_stall_secs).sum::<f64>()
        );
        assert_eq!(paid.ckpt_interval_secs, 120.0);
        // Per-event stall bound: never beyond faults × max stall.
        let bound = paid.records.iter().map(|r| r.events.len()).sum::<usize>() as f64
            * priced.recovery.max_stall_secs();
        assert!(paid.retry_stall_secs <= bound + 1e-9);
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("nope"), None);
    }

    #[test]
    fn policy_all_is_the_documented_order() {
        // `--policy all` and fig11 rows rely on this exact order.
        assert_eq!(
            Policy::ALL.map(Policy::name),
            ["static", "warm-replan", "anytime", "preempt", "oracle"]
        );
        assert!(Policy::Preempt.runs_background());
        assert!(Policy::Anytime.runs_background());
        assert!(!Policy::Warm.runs_background());
    }
}
