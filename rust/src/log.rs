//! Minimal `log`-crate facade (the offline registry has no `log`).
//! Mirrors the subset the crate uses: the [`Log`] trait, level types,
//! `set_boxed_logger` / `set_max_level` / `max_level`, and the
//! `error!`..`trace!` macros, invoked as `crate::log::debug!(...)`.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log levels, most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Level filter (a [`Level`] or `Off`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    fn as_usize(self) -> usize {
        self as usize
    }
}

// Cross-type comparisons (`Level <= LevelFilter`), as in the log crate.
impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record (just the level; targets live on the record).
#[derive(Debug, Clone, Copy)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log record: level + module path target + formatted arguments.
pub struct Record<'a> {
    level: Level,
    target: &'a str,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &str {
        self.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }

    pub fn metadata(&self) -> Metadata {
        Metadata { level: self.level }
    }
}

/// Logger backend interface.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // Off until init

/// Install the global logger; errors if one is already set.
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), ()> {
    LOGGER.set(logger).map_err(|_| ())
}

/// Set the maximum enabled level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current maximum enabled level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level.as_usize() > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        logger.log(&Record { level, target, args });
    }
}

#[doc(hidden)]
#[macro_export]
macro_rules! __hetrl_log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::log::__log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[doc(hidden)]
#[macro_export]
macro_rules! __hetrl_log_error {
    ($($arg:tt)+) => { $crate::__hetrl_log!($crate::log::Level::Error, $($arg)+) };
}

#[doc(hidden)]
#[macro_export]
macro_rules! __hetrl_log_warn {
    ($($arg:tt)+) => { $crate::__hetrl_log!($crate::log::Level::Warn, $($arg)+) };
}

#[doc(hidden)]
#[macro_export]
macro_rules! __hetrl_log_info {
    ($($arg:tt)+) => { $crate::__hetrl_log!($crate::log::Level::Info, $($arg)+) };
}

#[doc(hidden)]
#[macro_export]
macro_rules! __hetrl_log_debug {
    ($($arg:tt)+) => { $crate::__hetrl_log!($crate::log::Level::Debug, $($arg)+) };
}

#[doc(hidden)]
#[macro_export]
macro_rules! __hetrl_log_trace {
    ($($arg:tt)+) => { $crate::__hetrl_log!($crate::log::Level::Trace, $($arg)+) };
}

pub use crate::__hetrl_log_debug as debug;
pub use crate::__hetrl_log_error as error;
pub use crate::__hetrl_log_info as info;
pub use crate::__hetrl_log_trace as trace;
pub use crate::__hetrl_log_warn as warn;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize as Counter;
    use std::sync::Arc;

    struct CountingLogger(Arc<Counter>);

    impl Log for CountingLogger {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= Level::Info
        }

        fn log(&self, record: &Record<'_>) {
            let _ = format!("{}", record.args());
            self.0.fetch_add(1, Ordering::Relaxed);
        }

        fn flush(&self) {}
    }

    #[test]
    fn filtering_and_dispatch() {
        let count = Arc::new(Counter::new(0));
        // The global logger may already be installed by another test
        // (logging::init) — only assert when we won the race.
        let ours = set_boxed_logger(Box::new(CountingLogger(Arc::clone(&count)))).is_ok();
        set_max_level(LevelFilter::Info);
        crate::log::info!("hello {}", 1);
        crate::log::debug!("filtered out");
        if ours {
            assert_eq!(count.load(Ordering::Relaxed), 1);
        }
        assert_eq!(max_level(), LevelFilter::Info);
    }

    #[test]
    fn level_order() {
        assert!(Level::Error < Level::Trace);
        assert!(LevelFilter::Off < LevelFilter::Error);
    }
}
