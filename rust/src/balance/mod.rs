//! Load balancing (paper §4.2): post-processing of an execution plan to
//! better fit heterogeneous devices.
//!
//! * **Data-level** — "adjusts the local batch sizes across GPUs within a
//!   DP group … based on estimates from the cost model": DP shares are
//!   re-weighted by each replica's aggregate achievable throughput.
//! * **Layer-level** — "adjusts the layer distribution across pipeline
//!   stages based on estimates from the cost model": layers are
//!   redistributed in proportion to each stage's effective compute.
//!
//! A third strategy from the paper — sequence-length-aware sample
//! routing (longer sequences to faster GPUs) — lives in the execution
//! engine ([`crate::engine`]), since it needs per-sample lengths.

use crate::plan::ExecutionPlan;
use crate::topology::DeviceTopology;
use crate::workflow::RlWorkflow;

/// Which strategies to apply (the Figure 4 ablation toggles these).
#[derive(Debug, Clone, Copy)]
pub struct BalanceConfig {
    pub data_level: bool,
    pub layer_level: bool,
}

impl Default for BalanceConfig {
    fn default() -> Self {
        BalanceConfig { data_level: true, layer_level: true }
    }
}

impl BalanceConfig {
    pub fn off() -> Self {
        BalanceConfig { data_level: false, layer_level: false }
    }
}

/// Apply the configured load-balancing strategies, returning the
/// (still-valid) adjusted plan.
pub fn apply(
    plan: &ExecutionPlan,
    wf: &RlWorkflow,
    topo: &DeviceTopology,
    cfg: BalanceConfig,
) -> ExecutionPlan {
    let mut out = plan.clone();
    for (t, tp) in out.task_plans.iter_mut().enumerate() {
        let task = &wf.tasks[t];
        if cfg.layer_level && tp.strategy.pp > 1 {
            tp.layer_split = balanced_layer_split(
                task.model.nl,
                tp.strategy.pp,
                &stage_speeds(tp, topo),
            );
        }
        if cfg.data_level && tp.strategy.dp > 1 {
            tp.dp_shares = balanced_dp_shares(tp, topo);
        }
        let _ = task.kind(); // kinds currently share the same policy
    }
    out
}

/// Effective compute of each pipeline stage: the slowest TP member's
/// achievable FLOPs times the TP degree, min-ed across DP replicas.
fn stage_speeds(tp: &crate::plan::TaskPlan, topo: &DeviceTopology) -> Vec<f64> {
    let s = tp.strategy;
    (0..s.pp)
        .map(|j| {
            let mut worst_replica = f64::INFINITY;
            for i in 0..s.dp {
                let group = tp.tp_group(i, j);
                let slowest = group
                    .iter()
                    .map(|&d| topo.devices[d].effective_flops())
                    .fold(f64::INFINITY, f64::min);
                worst_replica = worst_replica.min(slowest * s.tp as f64);
            }
            worst_replica
        })
        .collect()
}

/// Distribute `nl` layers over stages proportionally to `speeds`
/// (largest-remainder rounding, every stage ≥ 1 layer when `nl ≥ pp`).
///
/// Total function on degenerate inputs instead of panicking:
/// * non-finite or non-positive speeds are treated as 0 (a stage whose
///   speed cannot be measured gets only the 1-layer floor);
/// * all speeds unusable → uniform split;
/// * `pp > nl` (more stages than layers — no split with every stage
///   ≥ 1 exists) → one layer to each of the first `nl` stages, zeros
///   after, so the length/sum contract still holds for callers that
///   clamp the strategy afterwards.
pub fn balanced_layer_split(nl: usize, pp: usize, speeds: &[f64]) -> Vec<usize> {
    assert_eq!(speeds.len(), pp);
    assert!(pp >= 1, "need at least one stage");
    if pp > nl {
        let mut split = vec![0usize; pp];
        for s in split.iter_mut().take(nl) {
            *s = 1;
        }
        return split;
    }
    let clean: Vec<f64> = speeds
        .iter()
        .map(|&s| if s.is_finite() && s > 0.0 { s } else { 0.0 })
        .collect();
    let total: f64 = clean.iter().sum();
    if total <= 0.0 {
        return crate::plan::parallel::uniform_layer_split(nl, pp);
    }
    let speeds = &clean;
    // Ideal fractional shares with a 1-layer floor.
    let spare = nl - pp;
    let ideal: Vec<f64> = speeds.iter().map(|s| spare as f64 * s / total).collect();
    let mut split: Vec<usize> = ideal.iter().map(|x| 1 + x.floor() as usize).collect();
    let mut assigned: usize = split.iter().sum();
    // Largest remainders get the leftovers.
    let mut rema: Vec<(f64, usize)> = ideal
        .iter()
        .enumerate()
        .map(|(j, x)| (x - x.floor(), j))
        .collect();
    rema.sort_by(|a, b| crate::util::ford::cmp_f64(b.0, a.0).then(a.1.cmp(&b.1)));
    let mut k = 0;
    while assigned < nl {
        split[rema[k % pp].1] += 1;
        assigned += 1;
        k += 1;
    }
    debug_assert_eq!(split.iter().sum::<usize>(), nl);
    split
}

/// DP shares proportional to each replica's bottleneck-stage speed.
fn balanced_dp_shares(tp: &crate::plan::TaskPlan, topo: &DeviceTopology) -> Vec<f64> {
    let s = tp.strategy;
    let mut speeds = Vec::with_capacity(s.dp);
    for i in 0..s.dp {
        let mut bottleneck = f64::INFINITY;
        for j in 0..s.pp {
            let group = tp.tp_group(i, j);
            let slowest = group
                .iter()
                .map(|&d| topo.devices[d].effective_flops())
                .fold(f64::INFINITY, f64::min);
            bottleneck = bottleneck.min(slowest);
        }
        speeds.push(bottleneck.max(1.0));
    }
    let total: f64 = speeds.iter().sum();
    speeds.iter().map(|&x| x / total).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::CostModel;
    use crate::plan::{ParallelStrategy, TaskPlan};
    use crate::topology::{build_testbed, Scenario, TestbedSpec};
    use crate::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

    fn mixed_plan(wf: &RlWorkflow) -> ExecutionPlan {
        // Each task on a mixed slice: A100 machine + L4 machine
        // (device ids 0..8 are A100, 16..24 are L4 under interleaved
        // round-robin machine order).
        let mut task_plans = Vec::new();
        for task in &wf.tasks {
            let s = ParallelStrategy::new(2, 2, 4);
            let devs: Vec<usize> = (0..8).chain(16..24).collect();
            task_plans.push(TaskPlan::uniform(s, task.model.nl, devs));
        }
        ExecutionPlan {
            task_groups: vec![(0..wf.n_tasks()).collect()],
            gpu_groups: vec![(0..8).chain(16..24).collect()],
            task_plans,
        }
    }

    #[test]
    fn balanced_split_prefers_fast_stages() {
        let split = balanced_layer_split(36, 2, &[3.0, 1.0]);
        assert_eq!(split.iter().sum::<usize>(), 36);
        assert!(split[0] > split[1]);
        // Uniform speeds → uniform split.
        assert_eq!(balanced_layer_split(36, 4, &[1.0; 4]), vec![9, 9, 9, 9]);
        // Every stage keeps ≥ 1 layer even with extreme skew.
        let skew = balanced_layer_split(8, 4, &[1000.0, 1.0, 1.0, 1.0]);
        assert!(skew.iter().all(|&l| l >= 1));
        assert_eq!(skew.iter().sum::<usize>(), 8);
    }

    #[test]
    fn balanced_split_edge_cases_do_not_panic() {
        // pp > nl: no ≥1-per-stage split exists; the contract degrades
        // to len == pp, sum == nl, first nl stages get the layers.
        let degenerate = balanced_layer_split(3, 5, &[1.0; 5]);
        assert_eq!(degenerate.len(), 5);
        assert_eq!(degenerate.iter().sum::<usize>(), 3);
        assert_eq!(&degenerate[..3], &[1, 1, 1]);

        // Zero / negative / NaN / infinite speeds: valid uniform-ish
        // splits, never a panic.
        for speeds in [
            vec![0.0; 4],
            vec![-1.0; 4],
            vec![f64::NAN; 4],
            vec![f64::INFINITY; 4],
            vec![f64::NAN, 1.0, 1.0, f64::NAN],
            vec![0.0, 0.0, 2.0, 2.0],
        ] {
            let split = balanced_layer_split(36, 4, &speeds);
            assert_eq!(split.len(), 4, "{speeds:?}");
            assert_eq!(split.iter().sum::<usize>(), 36, "{speeds:?}");
            assert!(split.iter().all(|&l| l >= 1), "{speeds:?} -> {split:?}");
        }
        // Usable speeds still dominate unusable ones.
        let mixed = balanced_layer_split(36, 4, &[f64::NAN, 9.0, 9.0, f64::NAN]);
        assert!(mixed[1] > mixed[0] && mixed[2] > mixed[3], "{mixed:?}");
    }

    #[test]
    fn balancing_keeps_plan_valid_and_helps() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let job = JobConfig::default();
        let plan = mixed_plan(&wf);
        plan.validate(&wf, &topo, &job).unwrap();
        let cm = CostModel::new(&topo, &wf, &job);
        let before = cm.plan_cost(&plan).iter_time;

        let balanced = apply(&plan, &wf, &topo, BalanceConfig::default());
        balanced.validate(&wf, &topo, &job).unwrap();
        let after = cm.plan_cost(&balanced).iter_time;
        assert!(
            after <= before * 1.0001,
            "balancing should not hurt: {after} vs {before}"
        );
        // On a mixed A100+L4 slice it should measurably help.
        assert!(after < before * 0.98, "expected >2% gain: {after} vs {before}");
    }

    #[test]
    fn off_config_is_identity() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let plan = mixed_plan(&wf);
        let same = apply(&plan, &wf, &topo, BalanceConfig::off());
        assert_eq!(same, plan);
    }

    #[test]
    fn dp_shares_sum_to_one() {
        let topo = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
        let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_1b7());
        let plan = mixed_plan(&wf);
        let balanced = apply(&plan, &wf, &topo, BalanceConfig::default());
        for tp in &balanced.task_plans {
            let sum: f64 = tp.dp_shares.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(tp.dp_shares.iter().all(|&s| s > 0.0));
        }
    }
}
