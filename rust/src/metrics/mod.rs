//! Run records and result persistence: every bench writes its rows here
//! (JSON under `bench_out/`, overridable via `HETRL_RESULTS`) so
//! experiment write-ups can cite concrete files.

use crate::util::json::Json;
use std::io::Write;
use std::path::{Path, PathBuf};

/// One experiment record: a named table with rows of (label → value).
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub experiment: String,
    pub fields: Vec<String>,
    pub rows: Vec<Vec<Json>>,
}

impl RunRecord {
    pub fn new(experiment: &str, fields: &[&str]) -> Self {
        RunRecord {
            experiment: experiment.to_string(),
            fields: fields.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<Json>) {
        assert_eq!(row.len(), self.fields.len(), "row arity");
        self.rows.push(row);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("experiment", Json::str(&self.experiment)),
            (
                "fields",
                Json::arr(self.fields.iter().map(|f| Json::str(f))),
            ),
            (
                "rows",
                Json::arr(self.rows.iter().map(|r| Json::Arr(r.clone()))),
            ),
        ])
    }

    /// Write `<dir>/<experiment>.json` (creating the directory).
    pub fn save(&self, dir: &str) -> std::io::Result<PathBuf> {
        let dir = Path::new(dir);
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.experiment.replace([' ', '/'], "_")));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.to_json().pretty().as_bytes())?;
        Ok(path)
    }
}

/// Bench output directory: `HETRL_RESULTS` env override, else
/// `bench_out/` (kept out of the way of source trees and git).
pub fn results_dir() -> String {
    // detlint:allow(D4): output directory override only — never feeds search results
    std::env::var("HETRL_RESULTS").unwrap_or_else(|_| "bench_out".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_roundtrip() {
        let mut r = RunRecord::new("fig3/test", &["scenario", "throughput"]);
        r.push(vec![Json::str("single-region"), Json::num(123.4)]);
        let j = r.to_json();
        assert_eq!(j.get("experiment").as_str(), Some("fig3/test"));
        assert_eq!(j.get("rows").at(0).at(1).as_f64(), Some(123.4));
    }

    #[test]
    fn save_writes_file() {
        let dir = std::env::temp_dir().join("hetrl_metrics_test");
        let mut r = RunRecord::new("smoke", &["a"]);
        r.push(vec![Json::num(1.0)]);
        let p = r.save(dir.to_str().unwrap()).unwrap();
        let content = std::fs::read_to_string(p).unwrap();
        assert!(content.contains("smoke"));
        let _ = std::fs::remove_dir_all(dir);
    }
}
