//! Property-based tests for the elastic subsystem (via the in-crate
//! `testing` framework):
//!
//! * replaying the same seed + event trace is bit-for-bit deterministic;
//! * a replan (warm or repair-only) never violates plan constraints
//!   C1–C3 against the post-event fleet snapshot;
//! * event traces are internally consistent for every seed.

use hetrl::elastic::{
    generate_trace, plan_to_base, repair_plan, replay, ClusterEvent, FleetState, Policy,
    Replanner, TraceConfig,
};
use hetrl::testing::fixtures::{small_replan_cfg, small_replay_cfg, small_spec, tiny_wf};
use hetrl::testing::{check_seeded, Gen};
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::workflow::JobConfig;

#[test]
fn prop_replay_deterministic_per_seed() {
    let wf = tiny_wf();
    let job = JobConfig::tiny();
    check_seeded(
        "replay(seed) == replay(seed), bit for bit",
        4,
        0xD15C0,
        Gen::usize_range(0, 1000),
        |&seed| {
            let run = |policy| {
                replay(
                    Scenario::MultiCountry,
                    &small_spec(),
                    &wf,
                    &job,
                    policy,
                    &small_replay_cfg(),
                    seed as u64,
                )
            };
            Policy::ALL.iter().all(|&p| {
                let a = run(p);
                let b = run(p);
                a == b
            })
        },
    );
}

#[test]
fn prop_replan_respects_constraints_c1_c3() {
    let wf = tiny_wf();
    let job = JobConfig::tiny();
    let base = build_testbed(Scenario::MultiRegionHybrid, &small_spec());
    check_seeded(
        "warm replan after random events validates (C1-C3)",
        8,
        0xC1C3,
        Gen::usize_range(0, 10_000),
        |&seed| {
            let seed = seed as u64;
            let mut fleet = FleetState::new(base.clone());
            let (topo0, map0) = fleet.snapshot();
            let mut rp = Replanner::new(seed, small_replan_cfg());
            let Some(plan0) = rp.cold_plan(&topo0, &wf, &job).plan else {
                return false; // full fleet must always be schedulable
            };
            if plan0.validate(&wf, &topo0, &job).is_err() {
                return false;
            }
            let incumbent = plan_to_base(&plan0, &map0);

            // Apply a random slice of a generated trace.
            let trace = generate_trace(
                &base,
                &TraceConfig { horizon: 8, n_events: 3, ..TraceConfig::default() },
                seed,
            );
            for e in &trace {
                fleet.apply(&e.event);
            }
            let (topo1, map1) = fleet.snapshot();
            let b2n = FleetState::base_to_snapshot(&map1);

            // Repair-only path.
            if let Some(repaired) = repair_plan(&incumbent, &wf, &job, &topo1, &b2n, seed) {
                if repaired.validate(&wf, &topo1, &job).is_err() {
                    return false;
                }
            }
            // Warm replan path.
            let out = rp.replan(&topo1, &wf, &job, &incumbent, &b2n);
            match out.plan {
                Some(p) => p.validate(&wf, &topo1, &job).is_ok(),
                // A feasible plan must exist: traces never drop below
                // half the machines and the tiny job fits on one.
                None => false,
            }
        },
    );
}

#[test]
fn prop_trace_consistency() {
    let base = build_testbed(Scenario::MultiContinent, &TestbedSpec::default());
    check_seeded(
        "traces: sorted, legal transitions, machine floor",
        60,
        0x7ACE,
        Gen::usize_range(0, 100_000),
        |&seed| {
            let cfg = TraceConfig { horizon: 20, n_events: 10, ..TraceConfig::default() };
            let trace = generate_trace(&base, &cfg, seed as u64);
            if trace.len() != cfg.n_events {
                return false;
            }
            // Sorted by iteration.
            if trace.windows(2).any(|w| w[0].at_iter > w[1].at_iter) {
                return false;
            }
            // Legal transitions + floor.
            let mut active: Vec<bool> = vec![true; 8];
            for e in &trace {
                match e.event {
                    ClusterEvent::MachinePreempt { machine }
                    | ClusterEvent::MachineLeave { machine } => {
                        if !active[machine] {
                            return false; // departed twice
                        }
                        active[machine] = false;
                    }
                    ClusterEvent::MachineJoin { machine } => {
                        if active[machine] {
                            return false; // joined while active
                        }
                        active[machine] = true;
                    }
                    ClusterEvent::StragglerOnset { slowdown, .. } => {
                        if !(0.0..=1.0).contains(&slowdown) {
                            return false;
                        }
                    }
                    ClusterEvent::LinkDegrade { lat_factor, bw_factor, .. } => {
                        if lat_factor < 1.0 || !(0.0..=1.0).contains(&bw_factor) {
                            return false;
                        }
                    }
                    _ => {}
                }
                if active.iter().filter(|&&a| a).count() < 4 {
                    return false; // below the 50% machine floor
                }
            }
            true
        },
    );
}
