//! Properties of the anytime background search (`hetrl replay
//! --policy anytime`):
//!
//! * **bit-determinism across thread counts** — the anytime budget is
//!   accounted in sim-time through the shared eval ledger and arms
//!   merge in index order, so the deterministic projection of a replay
//!   (plans, costs, eval counts, incumbent objectives) is identical at
//!   1, 2 and 8 worker threads for the same seed;
//! * **monotone incumbent** — within each inter-event window the
//!   anytime incumbent's objective is non-increasing (it resets when a
//!   barrier reseeds the service);
//! * **ledger cap** — background evaluations never exceed the sim-time
//!   allowance (`evals_per_sim_sec × Σ iter_secs`) and each step stays
//!   under `max_step_evals`;
//! * **never worse than warm** — on every scenario/seed pair tested,
//!   the anytime replay's total cost is no worse than the warm
//!   policy's. The barrier merge guarantees the anytime objective is
//!   ≤ warm's under equal pre-event state; once trajectories diverge
//!   the dominance is empirical, so the per-pair check carries a small
//!   simulation-noise tolerance and the aggregate a tight one.

use hetrl::elastic::{replay, Policy, ReplayConfig, ReplayResult};
use hetrl::testing::fixtures;
use hetrl::topology::Scenario;
use hetrl::workflow::JobConfig;

fn anytime_cfg(threads: usize) -> ReplayConfig {
    fixtures::background_replay_cfg(threads)
}

/// The deterministic projection of a replay: everything except the
/// cache hit/miss telemetry, which is approximate when threads > 1.
#[allow(clippy::type_complexity)]
fn fingerprint(
    r: &ReplayResult,
) -> Vec<(usize, Vec<String>, bool, usize, usize, u64, u64, usize, usize, u64)> {
    r.records
        .iter()
        .map(|x| {
            (
                x.iter,
                x.events.clone(),
                x.replanned,
                x.evals,
                x.anytime_evals,
                x.migration_secs.to_bits(),
                x.iter_secs.to_bits(),
                x.samples,
                x.active_gpus,
                x.anytime_cost.to_bits(),
            )
        })
        .collect()
}

#[test]
fn anytime_replay_bit_identical_across_thread_counts() {
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    for seed in [1u64, 5, 11] {
        let base = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Anytime,
            &anytime_cfg(1),
            seed,
        );
        assert!(base.total_secs.is_finite() && base.total_secs > 0.0);
        for threads in fixtures::test_threads().into_iter().filter(|&t| t != 1) {
            let out = replay(
                Scenario::MultiCountry,
                &fixtures::small_spec(),
                &wf,
                &job,
                Policy::Anytime,
                &anytime_cfg(threads),
                seed,
            );
            assert_eq!(
                fingerprint(&out),
                fingerprint(&base),
                "seed {seed}: anytime replay diverged at {threads} threads"
            );
            assert_eq!(out.total_secs.to_bits(), base.total_secs.to_bits());
            assert_eq!(out.total_evals, base.total_evals);
            assert_eq!(out.anytime_evals, base.anytime_evals);
        }
    }
}

#[test]
fn anytime_incumbent_monotone_between_events() {
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    for seed in [3u64, 9] {
        let r = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Anytime,
            &anytime_cfg(1),
            seed,
        );
        let mut prev = f64::INFINITY;
        for rec in &r.records {
            if !rec.events.is_empty() {
                // Barrier: the service reseeds from the merged plan.
                prev = f64::INFINITY;
            }
            assert!(
                rec.anytime_cost <= prev,
                "seed {seed}, iter {}: incumbent regressed {} -> {}",
                rec.iter,
                prev,
                rec.anytime_cost
            );
            prev = rec.anytime_cost;
        }
    }
}

#[test]
fn anytime_evals_never_exceed_ledger_allowance() {
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    for seed in [2u64, 7] {
        let cfg = anytime_cfg(1);
        let r = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Anytime,
            &cfg,
            seed,
        );
        let rate = cfg.replan.anytime.evals_per_sim_sec;
        let cap = cfg.replan.anytime.max_step_evals;
        let mut sim_secs = 0.0;
        let mut background = 0usize;
        for rec in &r.records {
            assert!(
                rec.anytime_evals <= cap,
                "seed {seed}, iter {}: step overran the cap: {}",
                rec.iter,
                rec.anytime_evals
            );
            sim_secs += rec.iter_secs;
            background += rec.anytime_evals;
        }
        assert_eq!(background, r.anytime_evals);
        assert!(
            (background as f64) <= sim_secs * rate + 1e-9,
            "seed {seed}: {background} background evals exceed the \
             sim-time allowance {:.1}",
            sim_secs * rate
        );
        assert!(background > 0, "seed {seed}: background search never ran");
    }
}

#[test]
fn anytime_replay_cost_no_worse_than_warm() {
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    let pairs = [
        (Scenario::MultiCountry, 7u64),
        (Scenario::MultiCountry, 13),
        (Scenario::MultiRegionHybrid, 3),
        (Scenario::MultiRegionHybrid, 5),
    ];
    let mut total_any = 0.0;
    let mut total_warm = 0.0;
    for (scenario, seed) in pairs {
        let warm = replay(
            scenario,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Warm,
            &anytime_cfg(1),
            seed,
        );
        let any = replay(
            scenario,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Anytime,
            &anytime_cfg(1),
            seed,
        );
        // Per pair: the barrier merge never picks a worse objective,
        // but simulated totals can wobble once trajectories diverge —
        // allow a small tolerance.
        assert!(
            any.total_secs <= warm.total_secs * 1.05 + 1e-9,
            "{} seed {seed}: anytime {:.2}s worse than warm {:.2}s",
            scenario.name(),
            any.total_secs,
            warm.total_secs
        );
        total_any += any.total_secs;
        total_warm += warm.total_secs;
    }
    assert!(
        total_any <= total_warm * 1.01 + 1e-9,
        "aggregate: anytime {total_any:.2}s vs warm {total_warm:.2}s"
    );
}

#[test]
fn anytime_policy_parses_and_is_listed() {
    assert_eq!(Policy::parse("anytime"), Some(Policy::Anytime));
    assert_eq!(Policy::parse(Policy::Anytime.name()), Some(Policy::Anytime));
    assert_eq!(Policy::ALL.len(), 5);
    assert!(Policy::ALL.contains(&Policy::Anytime));
}
