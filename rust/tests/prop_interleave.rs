//! Seeded-interleaving fuzz: replay-order invariance as an actively
//! tested guarantee.
//!
//! The component DES engine (`hetrl::simulator::component`) accepts a
//! [`ShuffleConfig`] that permutes the commit order of same-timestamp
//! ready ties *across* conflict components (ops transitively sharing a
//! resource, plus every zero-duration op coupled into its successors'
//! components — barriers and dur-0 queue ops release successors
//! *mid-instant*, so shuffling them independently would be unsound)
//! while preserving FIFO (program) order *within* each component. By
//! the argument in that module's docs, the entire observable schedule
//! — start, finish, busy and makespan — is bit-invariant under every
//! shuffle seed; the seed only perturbs the engine's internal event
//! interleaving. This suite makes that argument an executable
//! property (`python/tests/test_des_shuffle.py` runs the same fuzz
//! through a bit-exact Python port of the engine):
//!
//! * **DES level** — on seeded random op-DAGs (quantized durations, so
//!   ready-time ties genuinely occur), `simulate_with(Some(seed))` is
//!   bit-identical to `simulate()` for every fuzz seed, and shuffle-off
//!   is byte-identical to the pinned pre-component reference executor;
//! * **replay level** — for ≥ 8 shuffle seeds × 3 trace seeds × all
//!   five policies × both workflows (sync elastic replay and the
//!   bounded-staleness async replay, both with a seeded fault so the
//!   recovery charges are nonzero), the deterministic replay
//!   fingerprint (everything except cache hit/miss telemetry:
//!   per-record schedule/search telemetry, recovery charges, totals,
//!   async queue telemetry) is bit-identical to the unshuffled run;
//! * **thread matrix** — the invariance holds at every worker-thread
//!   count from `fixtures::test_threads()` (1/2/8 by default; `1` and
//!   `n` under `HETRL_TEST_THREADS=n`).

use hetrl::asyncrl::{replay_async, AsyncReplayConfig, AsyncReplayResult};
use hetrl::elastic::{replay, Policy, ReplayConfig, ReplayResult, TraceConfig};
use hetrl::simulator::ShuffleConfig;
use hetrl::testing::fixtures;
use hetrl::topology::Scenario;

/// ≥ 8 fuzz seeds, including 0 (xor with the conflict-component key
/// must still decorrelate) and a high-entropy one.
const SHUFFLE_SEEDS: [u64; 8] = [0, 2, 3, 5, 7, 11, 41, 0xDEAD_BEEF];

/// Trace seeds for the replay-level matrices.
const TRACE_SEEDS: [u64; 3] = [3, 9, 17];

/// Lean sync replay config: short trace and small search budgets so
/// the 3 × 5 × (1 + 8)-run matrix stays debug-mode friendly. Searches
/// dominate replay runtime and are shuffle-independent, so shrinking
/// them loses no coverage of the property under test. One seeded
/// transient fault plus recovery pricing keeps the recovery charges
/// (retry stall, rework, checkpoint writes) *nonzero*, so their
/// invariance is pinned for real rather than vacuously at 0.0.
fn lean_cfg(shuffle: Option<ShuffleConfig>, threads: usize) -> ReplayConfig {
    let mut cfg = fixtures::small_replay_cfg();
    cfg.iters = 4;
    cfg.trace = TraceConfig { horizon: 4, n_events: 2, fault_events: 1, ..TraceConfig::default() };
    cfg.replan.warm_budget = 16;
    cfg.replan.cold_budget = 48;
    cfg.replan.threads = threads;
    cfg.recovery = hetrl::costmodel::RecoveryModel::with_interval(120.0);
    cfg.shuffle = shuffle;
    cfg
}

/// Lean async replay config (staleness bound 2) over [`lean_cfg`].
fn lean_async_cfg(shuffle: Option<ShuffleConfig>, threads: usize) -> AsyncReplayConfig {
    let mut cfg = fixtures::async_replay_cfg(2, threads);
    cfg.base = lean_cfg(shuffle, threads);
    cfg
}

/// Per-record search/schedule telemetry (the `tests/prop_async.rs`
/// projection).
type RecordFp = (usize, Vec<String>, bool, usize, usize, usize, u64, u64, usize, usize, u64);
/// Per-record recovery charges (the `tests/prop_recover.rs` fields;
/// a separate tuple because std's tuple `PartialEq` stops at 12).
type RecoveryFp = (u64, u64, u64, bool);
/// Replay totals, recovery charges included.
type TotalsFp = (u64, u64, u64, u64, usize, usize, u64, usize);

/// The deterministic projection of a replay: everything except the
/// cache hit/miss telemetry, which is approximate when threads > 1.
/// Merges the `tests/prop_async.rs` projection with
/// `tests/prop_recover.rs`'s recovery charges and totals.
fn fingerprint(r: &ReplayResult) -> (Vec<RecordFp>, Vec<RecoveryFp>, TotalsFp) {
    let records = r
        .records
        .iter()
        .map(|x| {
            (
                x.iter,
                x.events.clone(),
                x.replanned,
                x.evals,
                x.anytime_evals,
                x.hypothesis_evals,
                x.migration_secs.to_bits(),
                x.iter_secs.to_bits(),
                x.samples,
                x.active_gpus,
                x.anytime_cost.to_bits(),
            )
        })
        .collect();
    let recovery = r
        .records
        .iter()
        .map(|x| {
            (
                x.retry_stall_secs.to_bits(),
                x.rework_secs.to_bits(),
                x.ckpt_secs.to_bits(),
                x.degraded,
            )
        })
        .collect();
    let totals = (
        r.total_secs.to_bits(),
        r.retry_stall_secs.to_bits(),
        r.rework_secs.to_bits(),
        r.ckpt_secs.to_bits(),
        r.ckpts,
        r.degraded_iters,
        r.ckpt_interval_secs.to_bits(),
        r.total_evals,
    );
    (records, recovery, totals)
}

/// [`fingerprint`] plus the async-side queue telemetry and staleness,
/// all bit-exact.
#[allow(clippy::type_complexity)]
fn async_fingerprint(
    r: &AsyncReplayResult,
) -> (
    (Vec<RecordFp>, Vec<RecoveryFp>, TotalsFp),
    Vec<(u64, usize, u64, usize)>,
    usize,
) {
    (
        fingerprint(&r.base),
        r.queue
            .iter()
            .map(|q| {
                (
                    q.queue_depth_mean.to_bits(),
                    q.queue_depth_max,
                    q.producer_stall_secs.to_bits(),
                    q.max_staleness,
                )
            })
            .collect(),
        r.max_staleness,
    )
}

#[test]
fn des_outcome_bit_invariant_under_every_shuffle_seed() {
    // Random DAGs with quantized (tie-rich) durations: every fuzz seed
    // must reproduce the unshuffled outcome to the last bit — start
    // and finish of every op, per-resource busy time, makespan.
    for graph_seed in 0..6u64 {
        let g = fixtures::random_sim_graph(graph_seed, 150, 4);
        let base = g.simulate();
        for &s in &SHUFFLE_SEEDS {
            let shuffled = g.simulate_with(Some(ShuffleConfig { seed: s }));
            assert_eq!(
                shuffled.makespan, base.makespan,
                "graph {graph_seed}, shuffle {s}: makespan diverged"
            );
            assert_eq!(shuffled.start, base.start, "graph {graph_seed}, shuffle {s}: start");
            assert_eq!(shuffled.finish, base.finish, "graph {graph_seed}, shuffle {s}: finish");
            assert_eq!(shuffled.busy, base.busy, "graph {graph_seed}, shuffle {s}: busy");
        }
    }
}

#[test]
fn shuffle_off_is_byte_identical_to_the_reference_executor() {
    // The pre-PR contract: with no ShuffleConfig, the component engine
    // commits ops in exactly the legacy FIFO `(ready_time, op_id)`
    // order. The pinned reference executor *is* the pre-PR loop, so
    // equality here is the byte-identity pin for shuffle-off mode.
    for graph_seed in 0..6u64 {
        let g = fixtures::random_sim_graph(graph_seed, 150, 4);
        let off = g.simulate_with(None);
        let fifo = g.simulate();
        let reference = g.simulate_reference();
        assert_eq!(off.makespan, fifo.makespan, "graph {graph_seed}: simulate_with(None) drifted");
        assert_eq!(off.start, fifo.start, "graph {graph_seed}");
        assert_eq!(off.finish, fifo.finish, "graph {graph_seed}");
        assert_eq!(off.busy, fifo.busy, "graph {graph_seed}");
        assert_eq!(off.makespan, reference.makespan, "graph {graph_seed}: vs reference");
        assert_eq!(off.start, reference.start, "graph {graph_seed}: vs reference");
        assert_eq!(off.finish, reference.finish, "graph {graph_seed}: vs reference");
        assert_eq!(off.busy, reference.busy, "graph {graph_seed}: vs reference");
    }
}

#[test]
fn sync_replay_fingerprint_invariant_under_shuffle() {
    // 3 trace seeds × all five policies × 8 shuffle seeds, sync
    // workflow: every shuffled replay must reproduce the unshuffled
    // fingerprint bit-for-bit.
    let wf = fixtures::tiny_wf();
    let job = hetrl::workflow::JobConfig::tiny();
    let spec = fixtures::small_spec();
    for policy in Policy::ALL {
        for &seed in &TRACE_SEEDS {
            let base = replay(
                Scenario::MultiCountry,
                &spec,
                &wf,
                &job,
                policy,
                &lean_cfg(None, 1),
                seed,
            );
            let want = fingerprint(&base);
            for &s in &SHUFFLE_SEEDS {
                let got = replay(
                    Scenario::MultiCountry,
                    &spec,
                    &wf,
                    &job,
                    policy,
                    &lean_cfg(Some(ShuffleConfig { seed: s }), 1),
                    seed,
                );
                assert_eq!(
                    fingerprint(&got),
                    want,
                    "sync replay not shuffle-invariant ({policy:?}, trace seed {seed}, shuffle {s})"
                );
            }
        }
    }
}

#[test]
fn async_replay_fingerprint_invariant_under_shuffle() {
    // Same matrix for the bounded-staleness async workflow (k = 2):
    // the fingerprint here additionally pins the queue telemetry
    // (depths, producer stall, staleness) bit-exactly.
    let wf = fixtures::tiny_wf();
    let job = fixtures::async_job();
    let spec = fixtures::small_spec();
    for policy in Policy::ALL {
        for &seed in &TRACE_SEEDS {
            let base = replay_async(
                Scenario::MultiCountry,
                &spec,
                &wf,
                &job,
                policy,
                &lean_async_cfg(None, 1),
                seed,
            );
            let want = async_fingerprint(&base);
            for &s in &SHUFFLE_SEEDS {
                let got = replay_async(
                    Scenario::MultiCountry,
                    &spec,
                    &wf,
                    &job,
                    policy,
                    &lean_async_cfg(Some(ShuffleConfig { seed: s }), 1),
                    seed,
                );
                assert_eq!(
                    async_fingerprint(&got),
                    want,
                    "async replay not shuffle-invariant ({policy:?}, trace seed {seed}, shuffle {s})"
                );
            }
        }
    }
}

#[test]
fn shuffle_invariance_holds_at_every_thread_count() {
    // A reduced combo swept over the worker-thread matrix
    // (`HETRL_TEST_THREADS` honored: default {1, 2, 8}, `n` ⇒ {1, n}).
    // The shuffled fingerprint must equal the unshuffled one at the
    // *same* thread count — and the fingerprint itself is already
    // pinned thread-invariant by tests/prop_async.rs, so transitively
    // every (threads, shuffle) cell agrees.
    let wf = fixtures::tiny_wf();
    let job = fixtures::async_job();
    let spec = fixtures::small_spec();
    let seed = TRACE_SEEDS[0];
    for threads in fixtures::test_threads() {
        let sync_base = fingerprint(&replay(
            Scenario::MultiCountry,
            &spec,
            &wf,
            &job,
            Policy::Warm,
            &lean_cfg(None, threads),
            seed,
        ));
        let async_base = async_fingerprint(&replay_async(
            Scenario::MultiCountry,
            &spec,
            &wf,
            &job,
            Policy::Warm,
            &lean_async_cfg(None, threads),
            seed,
        ));
        for &s in &SHUFFLE_SEEDS[..2] {
            let shuffle = Some(ShuffleConfig { seed: s });
            let sync_got = fingerprint(&replay(
                Scenario::MultiCountry,
                &spec,
                &wf,
                &job,
                Policy::Warm,
                &lean_cfg(shuffle, threads),
                seed,
            ));
            assert_eq!(
                sync_got, sync_base,
                "sync replay not shuffle-invariant at {threads} threads (shuffle {s})"
            );
            let async_got = async_fingerprint(&replay_async(
                Scenario::MultiCountry,
                &spec,
                &wf,
                &job,
                Policy::Warm,
                &lean_async_cfg(shuffle, threads),
                seed,
            ));
            assert_eq!(
                async_got, async_base,
                "async replay not shuffle-invariant at {threads} threads (shuffle {s})"
            );
        }
    }
}
