//! Properties of predictive preemption (`hetrl replay --policy
//! preempt`):
//!
//! * **bit-determinism across thread counts** — the hypothesis search
//!   runs on the same engine as the primary incumbent, its allowance
//!   half is a pure function of the step quota
//!   (`engine::split_allowance`) and arms merge in index order, so the
//!   deterministic projection of a preempt replay is identical at 1, 2
//!   and 8 worker threads for the same seed;
//! * **no worse than anytime on noticed traces** — the three-way
//!   barrier merge only ever *adds* a candidate over the anytime
//!   policy's merge, so with advance notice the preempt replay's total
//!   cost tracks the anytime policy's. Once trajectories diverge the
//!   dominance is empirical (the hypothesis half starves the primary
//!   incumbent slightly), so the per-pair check carries a small
//!   simulation-noise tolerance and the aggregate a tighter one;
//! * **zero-notice degeneracy** — with all notice stripped
//!   (`TraceConfig::notice_override = Some(0.0)`) no hypothesis is
//!   ever primed and the preempt policy replays **bit-identically** to
//!   the anytime policy (same service seed, same allowance, same
//!   merge);
//! * **allowance split cap** — primary + hypothesis background evals
//!   together never exceed the sim-time allowance
//!   (`evals_per_sim_sec × Σ iter_secs`) or the per-step cap — the
//!   hypothesis spends the warm incumbent's spare cycles, never new
//!   budget.

use hetrl::elastic::{replay, Policy, ReplayConfig, ReplayResult};
use hetrl::testing::fixtures;
use hetrl::topology::Scenario;
use hetrl::workflow::JobConfig;

/// The background suite config with the notice window pinned:
/// `Some(n)` gives every machine-loss event exactly `n` seconds of
/// notice, `Some(0.0)` strips notice entirely.
fn preempt_cfg(threads: usize, notice: Option<f64>) -> ReplayConfig {
    let mut cfg = fixtures::background_replay_cfg(threads);
    cfg.trace.notice_override = notice;
    cfg
}

/// A notice window so large it covers any simulated lead time — every
/// machine loss in the trace is forecast from iteration 0.
const FULL_NOTICE: Option<f64> = Some(1e9);

/// The deterministic projection of a replay: everything except the
/// cache hit/miss telemetry, which is approximate when threads > 1.
#[allow(clippy::type_complexity)]
fn fingerprint(
    r: &ReplayResult,
) -> Vec<(usize, Vec<String>, bool, usize, usize, usize, u64, u64, usize, usize, u64)> {
    r.records
        .iter()
        .map(|x| {
            (
                x.iter,
                x.events.clone(),
                x.replanned,
                x.evals,
                x.anytime_evals,
                x.hypothesis_evals,
                x.migration_secs.to_bits(),
                x.iter_secs.to_bits(),
                x.samples,
                x.active_gpus,
                x.anytime_cost.to_bits(),
            )
        })
        .collect()
}

#[test]
fn preempt_replay_bit_identical_across_thread_counts() {
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    for seed in [1u64, 5, 11] {
        let base = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Preempt,
            &preempt_cfg(1, FULL_NOTICE),
            seed,
        );
        assert!(base.total_secs.is_finite() && base.total_secs > 0.0);
        for threads in fixtures::test_threads().into_iter().filter(|&t| t != 1) {
            let out = replay(
                Scenario::MultiCountry,
                &fixtures::small_spec(),
                &wf,
                &job,
                Policy::Preempt,
                &preempt_cfg(threads, FULL_NOTICE),
                seed,
            );
            assert_eq!(
                fingerprint(&out),
                fingerprint(&base),
                "seed {seed}: preempt replay diverged at {threads} threads"
            );
            assert_eq!(out.total_secs.to_bits(), base.total_secs.to_bits());
            assert_eq!(out.total_evals, base.total_evals);
            assert_eq!(out.anytime_evals, base.anytime_evals);
            assert_eq!(out.hypothesis_evals, base.hypothesis_evals);
        }
    }
}

#[test]
fn preempt_cost_no_worse_than_anytime_with_notice() {
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    let pairs = [
        (Scenario::MultiCountry, 7u64),
        (Scenario::MultiCountry, 13),
        (Scenario::MultiRegionHybrid, 3),
        (Scenario::MultiRegionHybrid, 5),
    ];
    let mut total_pre = 0.0;
    let mut total_any = 0.0;
    for (scenario, seed) in pairs {
        let any = replay(
            scenario,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Anytime,
            &preempt_cfg(1, FULL_NOTICE),
            seed,
        );
        let pre = replay(
            scenario,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Preempt,
            &preempt_cfg(1, FULL_NOTICE),
            seed,
        );
        // Per pair: the barrier merge never picks a worse objective
        // than anytime's candidates, but simulated totals can wobble
        // once trajectories diverge — allow a small tolerance.
        assert!(
            pre.total_secs <= any.total_secs * 1.05 + 1e-9,
            "{} seed {seed}: preempt {:.2}s worse than anytime {:.2}s",
            scenario.name(),
            pre.total_secs,
            any.total_secs
        );
        total_pre += pre.total_secs;
        total_any += any.total_secs;
    }
    assert!(
        total_pre <= total_any * 1.02 + 1e-9,
        "aggregate: preempt {total_pre:.2}s vs anytime {total_any:.2}s"
    );
}

#[test]
fn zero_notice_degenerates_to_anytime_bit_identically() {
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    for seed in [2u64, 9, 17] {
        let any = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Anytime,
            &preempt_cfg(1, Some(0.0)),
            seed,
        );
        let pre = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Preempt,
            &preempt_cfg(1, Some(0.0)),
            seed,
        );
        assert_eq!(
            fingerprint(&pre),
            fingerprint(&any),
            "seed {seed}: zero-notice preempt diverged from anytime"
        );
        assert_eq!(pre.total_secs.to_bits(), any.total_secs.to_bits());
        assert_eq!(pre.total_evals, any.total_evals);
        assert_eq!(pre.anytime_evals, any.anytime_evals);
        assert_eq!(pre.hypothesis_evals, 0, "hypothesis ran without notice");
    }
}

#[test]
fn allowance_split_never_exceeds_sim_time_budget() {
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    let mut hypothesis_total = 0usize;
    for seed in [2u64, 7, 12] {
        let cfg = preempt_cfg(1, FULL_NOTICE);
        let r = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Preempt,
            &cfg,
            seed,
        );
        let rate = cfg.replan.anytime.evals_per_sim_sec;
        let cap = cfg.replan.anytime.max_step_evals;
        let mut sim_secs = 0.0;
        let mut background = 0usize;
        for rec in &r.records {
            assert!(
                rec.anytime_evals + rec.hypothesis_evals <= cap,
                "seed {seed}, iter {}: split overran the step cap: {} + {}",
                rec.iter,
                rec.anytime_evals,
                rec.hypothesis_evals
            );
            // The hypothesis quota is the primary-biased half of the
            // step quota, so its spend can never exceed half the cap.
            assert!(
                rec.hypothesis_evals <= cap / 2,
                "seed {seed}, iter {}: hypothesis spent {} > half-cap {}",
                rec.iter,
                rec.hypothesis_evals,
                cap / 2
            );
            sim_secs += rec.iter_secs;
            background += rec.anytime_evals + rec.hypothesis_evals;
        }
        assert_eq!(r.anytime_evals + r.hypothesis_evals, background);
        assert!(
            (background as f64) <= sim_secs * rate + 1e-9,
            "seed {seed}: {background} background evals exceed the \
             sim-time allowance {:.1}",
            sim_secs * rate
        );
        assert!(r.anytime_evals > 0, "seed {seed}: background search never ran");
        hypothesis_total += r.hypothesis_evals;
    }
    // With every loss fully noticed, the hypothesis search must have
    // run somewhere across the seeds.
    assert!(hypothesis_total > 0, "hypothesis search never ran on any seed");
}

#[test]
fn preempt_policy_parses_and_is_listed() {
    assert_eq!(Policy::parse("preempt"), Some(Policy::Preempt));
    assert_eq!(Policy::parse("predictive"), Some(Policy::Preempt));
    assert_eq!(Policy::parse(Policy::Preempt.name()), Some(Policy::Preempt));
    assert_eq!(
        Policy::ALL.map(Policy::name),
        ["static", "warm-replan", "anytime", "preempt", "oracle"],
        "the documented --policy all order"
    );
}
