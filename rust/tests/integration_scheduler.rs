//! Integration: schedulers → plans → cost model across scenarios.

use hetrl::balance::{self, BalanceConfig};
use hetrl::costmodel::CostModel;
use hetrl::scheduler::{
    Budget, PureEaScheduler, RandomScheduler, Scheduler, ShaEaScheduler, StreamRlScheduler,
    VerlScheduler,
};
use hetrl::testing::fixtures;
use hetrl::topology::Scenario;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

fn env(
    scenario: Scenario,
    algo: Algo,
    mode: Mode,
) -> (RlWorkflow, hetrl::topology::DeviceTopology, JobConfig) {
    fixtures::env_with(scenario, algo, mode, ModelSpec::qwen_4b())
}

#[test]
fn every_scheduler_yields_valid_plans_everywhere() {
    for scenario in [Scenario::SingleRegion, Scenario::MultiContinent] {
        let (wf, topo, job) = env(scenario, Algo::Grpo, Mode::Sync);
        let mut scheds: Vec<Box<dyn Scheduler>> = vec![
            Box::new(ShaEaScheduler::new(1)),
            Box::new(VerlScheduler::new(1)),
            Box::new(StreamRlScheduler::new(1)),
            Box::new(PureEaScheduler::new(1)),
            Box::new(RandomScheduler::new(1)),
        ];
        for s in scheds.iter_mut() {
            let out = s.schedule(&topo, &wf, &job, Budget::timed(150, 30.0));
            let plan = out
                .plan
                .unwrap_or_else(|| panic!("{} found no plan on {}", s.name(), scenario.name()));
            plan.validate(&wf, &topo, &job)
                .unwrap_or_else(|e| panic!("{} invalid plan: {e}", s.name()));
            assert!(out.cost.is_finite());
        }
    }
}

#[test]
fn hetrl_beats_verl_on_wan() {
    // The paper's core claim, checked on the cost model: HetRL's
    // heterogeneity-aware search finds faster plans than verl in
    // geo-distributed scenarios.
    let (wf, topo, job) = env(Scenario::MultiContinent, Algo::Ppo, Mode::Sync);
    let sha = ShaEaScheduler::new(2).schedule(&topo, &wf, &job, Budget::timed(700, 60.0));
    let verl = VerlScheduler::new(2).schedule(&topo, &wf, &job, Budget::timed(200, 30.0));
    assert!(sha.cost.is_finite() && verl.cost.is_finite());
    assert!(
        sha.cost < verl.cost,
        "HetRL {} should beat verl {}",
        sha.cost,
        verl.cost
    );
}

#[test]
fn traces_are_monotone() {
    let (wf, topo, job) = env(Scenario::MultiCountry, Algo::Grpo, Mode::Sync);
    let out = ShaEaScheduler::new(3).schedule(&topo, &wf, &job, Budget::timed(300, 30.0));
    let costs: Vec<f64> = out.trace.iter().map(|p| p.best_cost).collect();
    assert!(!costs.is_empty());
    for w in costs.windows(2) {
        assert!(w[1] <= w[0], "incumbent must only improve: {costs:?}");
    }
}

#[test]
fn balancing_composes_with_scheduler_output() {
    let (wf, topo, job) = env(Scenario::MultiRegionHybrid, Algo::Grpo, Mode::Sync);
    let cm = CostModel::new(&topo, &wf, &job);
    for seed in [1, 2] {
        let out = ShaEaScheduler::new(seed).schedule(&topo, &wf, &job, Budget::timed(250, 30.0));
        let plan = out.plan.unwrap();
        let balanced = balance::apply(&plan, &wf, &topo, BalanceConfig::default());
        balanced.validate(&wf, &topo, &job).unwrap();
        let before = cm.plan_cost(&plan).iter_time;
        let after = cm.plan_cost(&balanced).iter_time;
        assert!(after <= before * 1.0001, "balancing hurt: {after} vs {before}");
    }
}

#[test]
fn async_plans_not_slower_than_sync_for_hetrl() {
    let (wf_s, topo, job) = env(Scenario::MultiCountry, Algo::Grpo, Mode::Sync);
    let (wf_a, _, _) = env(Scenario::MultiCountry, Algo::Grpo, Mode::Async);
    let sync = ShaEaScheduler::new(4).schedule(&topo, &wf_s, &job, Budget::timed(400, 40.0));
    let asyn = ShaEaScheduler::new(4).schedule(&topo, &wf_a, &job, Budget::timed(400, 40.0));
    assert!(
        asyn.cost <= sync.cost * 1.10,
        "async {} vs sync {}",
        asyn.cost,
        sync.cost
    );
}
