//! Property-based tests on coordinator invariants, via the in-crate
//! `testing` framework (proptest substitute): plan validity closed under
//! the EA's operators, cost-model monotonicities, SHA budget respect,
//! solver exactness on random instances, simulator lower bounds.

use hetrl::costmodel::{ring_minmax, CostModel};
use hetrl::plan::parallel::uniform_layer_split;
use hetrl::scheduler::ea::swap_devices;
use hetrl::scheduler::{Budget, Scheduler, ShaEaScheduler};
use hetrl::solver::{solve_milp, BnbConfig, Cmp, Lp};
use hetrl::testing::fixtures::{self, random_plan};
use hetrl::testing::{check_seeded, Gen};
use hetrl::topology::{DeviceTopology, Scenario};
use hetrl::util::rng::Rng;
use hetrl::workflow::{JobConfig, RlWorkflow};

fn env() -> (RlWorkflow, DeviceTopology, JobConfig) {
    fixtures::env(Scenario::MultiCountry)
}

#[test]
fn prop_plan_validity_closed_under_device_swap() {
    let (wf, topo, job) = env();
    check_seeded(
        "validate(swap_devices(valid plan)) holds",
        40,
        7,
        Gen::pair(Gen::usize_range(0, 1_000_000), Gen::usize_range(0, 64 * 64)),
        |&(seed, pair)| {
            let Some(mut plan) = random_plan(&wf, &topo, &job, seed as u64) else {
                return true; // generation failed: vacuous
            };
            let (a, b) = (pair / 64, pair % 64);
            // Swaps may move a big tasklet onto a small GPU: structural
            // validity must hold; OOM is the only acceptable failure.
            swap_devices(&mut plan, a, b);
            match plan.validate(&wf, &topo, &job) {
                Ok(()) => true,
                Err(hetrl::plan::PlanError::OutOfMemory { .. }) => true,
                Err(e) => {
                    eprintln!("structural violation after swap({a},{b}): {e}");
                    false
                }
            }
        },
    );
}

#[test]
fn prop_uniform_layer_split_well_formed() {
    check_seeded(
        "layer split: right length, sums, min 1",
        300,
        11,
        Gen::pair(Gen::usize_range(1, 100), Gen::usize_range(1, 17)),
        |&(nl, pp)| {
            if pp > nl {
                return true;
            }
            let s = uniform_layer_split(nl, pp);
            s.len() == pp && s.iter().sum::<usize>() == nl && s.iter().all(|&x| x >= 1)
        },
    );
}

#[test]
fn prop_ring_minmax_never_beats_best_edge_nor_exceeds_worst() {
    let (_, topo, _) = env();
    check_seeded(
        "min edge ≤ ring bottleneck ≤ max edge (over the group)",
        120,
        13,
        Gen::vec(Gen::usize_range(0, 64), 2, 8),
        |devs| {
            let mut d = devs.clone();
            d.sort_unstable();
            d.dedup();
            if d.len() < 2 {
                return true;
            }
            let cv = 1e8;
            let ring = ring_minmax(&topo, &d, cv);
            let mut emin = f64::INFINITY;
            let mut emax: f64 = 0.0;
            for i in 0..d.len() {
                for j in 0..d.len() {
                    if i != j {
                        let e = topo.lat(d[i], d[j]) + cv / topo.bw(d[i], d[j]);
                        emin = emin.min(e);
                        emax = emax.max(e);
                    }
                }
            }
            ring >= emin - 1e-12 && ring <= emax + 1e-12
        },
    );
}

#[test]
fn prop_cost_model_monotone_in_bandwidth() {
    // Scaling all bandwidths up can never increase a plan's cost.
    let (wf, topo, job) = env();
    let cm = CostModel::new(&topo, &wf, &job);
    let mut fast = topo.clone();
    for row in fast.beta.iter_mut() {
        for b in row.iter_mut() {
            *b *= 4.0;
        }
    }
    let cm_fast = CostModel::new(&fast, &wf, &job);
    check_seeded(
        "4x bandwidth never hurts",
        25,
        17,
        Gen::usize_range(0, 1_000_000),
        |&seed| {
            let Some(plan) = random_plan(&wf, &topo, &job, seed as u64) else {
                return true;
            };
            let slow = cm.plan_cost(&plan).iter_time;
            let quick = cm_fast.plan_cost(&plan).iter_time;
            quick <= slow + 1e-9
        },
    );
}

#[test]
fn prop_sha_respects_eval_budget() {
    let (wf, topo, job) = env();
    check_seeded(
        "SHA-EA never exceeds the eval budget (quota-based rungs)",
        6,
        19,
        Gen::pair(Gen::usize_range(20, 300), Gen::usize_range(0, 1000)),
        |&(budget, seed)| {
            let out = ShaEaScheduler::new(seed as u64).schedule(
                &topo,
                &wf,
                &job,
                Budget::evals(budget),
            );
            out.evals <= budget
        },
    );
}

#[test]
fn prop_milp_matches_exhaustive_small_knapsacks() {
    let mut rng = Rng::new(23);
    for _case in 0..12 {
        let n = 7;
        let c: Vec<f64> = (0..n).map(|_| rng.range_f64(-4.0, 9.0)).collect();
        let w: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 4.0)).collect();
        let cap = rng.range_f64(3.0, 10.0);
        let mut lp = Lp::new(n, c.clone(), true);
        lp.constrain(w.iter().cloned().enumerate().collect(), Cmp::Le, cap);
        let cfg = BnbConfig { time_limit: 10.0, max_nodes: 20_000, gap: 1e-6 };
        let r = solve_milp(&lp, &(0..n).collect::<Vec<_>>(), &cfg);
        let mut best = 0.0f64;
        for mask in 0..(1usize << n) {
            let weight: f64 = (0..n).filter(|i| mask >> i & 1 == 1).map(|i| w[i]).sum();
            if weight <= cap + 1e-9 {
                best = best.max((0..n).filter(|i| mask >> i & 1 == 1).map(|i| c[i]).sum());
            }
        }
        assert!(r.optimal && (r.obj - best).abs() < 1e-5, "{} vs {best}", r.obj);
    }
}

#[test]
fn prop_simulator_makespan_at_least_critical_compute() {
    // Simulated iteration time can never undercut the slowest single
    // task's pure-compute lower bound by more than jitter allows.
    use hetrl::simulator::{simulate_plan, NoiseModel, SimConfig};
    let (wf, topo, job) = env();
    check_seeded(
        "makespan ≥ max over tasks of per-task busy span",
        6,
        29,
        Gen::usize_range(0, 1_000_000),
        |&seed| {
            let Some(plan) = random_plan(&wf, &topo, &job, seed as u64) else {
                return true;
            };
            let cfg = SimConfig { iters: 1, seed: 1, noise: NoiseModel::off(), shuffle: None };
            let r = simulate_plan(&topo, &wf, &job, &plan, &cfg);
            r.per_task
                .iter()
                .all(|&t| t <= r.iter_time + 1e-6)
        },
    );
}
