//! `detlint` self-check: fixtures that must trigger each rule D1–D5,
//! the allow-directive lifecycle (acceptance, unused rejection,
//! malformed rejection), the byte-for-byte pinned diagnostic format —
//! and the gate itself: the shipped tree must be lint-clean.
//!
//! Every banned token in this file lives inside a string literal, so
//! the self-check never flags its own fixtures.

use std::path::PathBuf;

use hetrl::lint::{check_source, fix_unused_allows, run_paths, Finding, Report, Rule};

fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule.id()).collect()
}

// ---- one fixture per rule ----------------------------------------------

#[test]
fn d1_wall_clock_fixture() {
    let src = "use std::time::Instant;\nfn f() { let t = Instant::now(); }\n";
    let f = check_source("src/scheduler/x.rs", src);
    assert_eq!(rules_of(&f), vec!["D1", "D1"]);
    assert_eq!((f[0].line, f[1].line), (1, 2));
    // The same source is fine in a telemetry module.
    assert!(check_source("src/util/logging.rs", src).is_empty());
    assert!(check_source("src/engine/grpo.rs", src).is_empty());
}

#[test]
fn d2_hash_collections_fixture() {
    let src = "use std::collections::{HashMap, HashSet};\n";
    let f = check_source("src/plan/x.rs", src);
    assert_eq!(rules_of(&f), vec!["D2", "D2"]);
    // No allowlist for D2 — even the cache must carry explicit allows.
    assert_eq!(check_source("src/costmodel/cache.rs", src).len(), 2);
}

#[test]
fn d3_nan_unsafe_comparator_fixture() {
    let src = "xs.sort_by(|a, b| a.cost.partial_cmp(&b.cost).unwrap());\n";
    let f = check_source("src/scheduler/x.rs", src);
    assert_eq!(rules_of(&f), vec!["D3"]);
    assert!(f[0].msg.contains("cmp_f64"));
    // A trait impl defines partial_cmp without comparing floats.
    let def = "impl PartialOrd for X { fn partial_cmp(&self, o: &Self) -> Option<Ordering> { self.0.partial_cmp(&o.0) } }";
    assert!(check_source("src/scheduler/x.rs", def).is_empty());
}

#[test]
fn d4_ambient_nondeterminism_fixture() {
    let src = "let n = std::thread::available_parallelism();\nlet v = std::env::var(\"X\");\nlet id = std::thread::current().id();\nlet s = RandomState::new();\n";
    let f = check_source("src/elastic/x.rs", src);
    assert_eq!(rules_of(&f), vec!["D4", "D4", "D4", "D4"]);
    // Sanctioned homes: the thread resolver and the test fixtures.
    assert!(check_source("src/scheduler/engine.rs", src).is_empty());
    assert!(check_source("src/testing/fixtures.rs", src).is_empty());
}

#[test]
fn d5_concurrency_inventory_fixture() {
    let relaxed = "let n = c.load(Ordering::Relaxed);\n";
    assert_eq!(rules_of(&check_source("src/engine/x.rs", relaxed)), vec!["D5"]);
    assert!(check_source("src/log.rs", relaxed).is_empty());

    let lock = "let g = m.lock().unwrap();\n";
    assert_eq!(rules_of(&check_source("src/engine/x.rs", lock)), vec!["D5"]);
    assert!(check_source("src/util/threadpool.rs", lock).is_empty());
    // The cost cache's Mutex entry was retired with the sharded-RwLock
    // rewrite: a `.lock()` there is a finding again.
    assert_eq!(rules_of(&check_source("src/costmodel/cache.rs", lock)), vec!["D5"]);

    let rw = "let shard = RwLock::new(0u64);\n";
    assert_eq!(rules_of(&check_source("src/engine/x.rs", rw)), vec!["D5"]);
    assert!(check_source("src/costmodel/cache.rs", rw).is_empty());

    // Nested acquisition in one statement needs a LOCK_ORDER entry even
    // inside an inventoried file.
    let nested = "let v = a.lock().unwrap().merge(b.lock().unwrap());\n";
    let f = check_source("src/util/threadpool.rs", nested);
    assert_eq!(rules_of(&f), vec!["D5"]);
    assert!(f[0].msg.contains("LOCK_ORDER"));
}

// ---- allow-directive lifecycle -----------------------------------------

#[test]
fn allow_comment_suppresses_trailing_and_standalone() {
    let trailing = "use std::collections::HashMap; // detlint:allow(D2): keyed lookups only\n";
    assert!(check_source("src/x.rs", trailing).is_empty());
    let standalone = "// detlint:allow(D1): telemetry probe\nuse std::time::Instant;\n";
    assert!(check_source("src/x.rs", standalone).is_empty());
    // Stacked standalone directives both reach the next code line.
    let stacked = "// detlint:allow(D1): telemetry probe\n// detlint:allow(D2): keyed lookups only\nuse std::time::Instant; use std::collections::HashMap;\n";
    assert!(check_source("src/x.rs", stacked).is_empty());
}

#[test]
fn unused_allow_is_rejected_and_fixable() {
    let src = "let x = 1; // detlint:allow(D3): nothing to suppress here\n";
    let f = check_source("src/x.rs", src);
    assert_eq!(rules_of(&f), vec!["A0"]);
    assert!(f[0].fixable, "unused allows are mechanically strippable");
    assert!(f[0].msg.contains("unused detlint:allow(D3)"));
}

#[test]
fn malformed_allow_is_rejected() {
    for src in [
        "// detlint:allow(D7): unknown rule\n",
        "// detlint:allow(A0): the meta rule cannot be suppressed\n",
        "// detlint:allow(D1) missing colon and reason\n",
        "// detlint:allow(D1):\n",
    ] {
        let f = check_source("src/x.rs", src);
        assert_eq!(rules_of(&f), vec!["A0"], "for fixture {src:?}");
        assert!(!f[0].fixable, "malformed directives need a human");
    }
}

#[test]
fn allow_in_doc_comment_or_string_is_inert() {
    // A doc comment showing the syntax is not a directive (and so can't
    // go stale); same for string literals.
    let doc = "/// detlint:allow(D2): example in rustdoc\nlet x = 1;\n";
    assert!(check_source("src/x.rs", doc).is_empty());
    let s = "let msg = \"detlint:allow(D2): in a string\";\n";
    assert!(check_source("src/x.rs", s).is_empty());
}

// ---- output format ------------------------------------------------------

#[test]
fn diagnostics_are_pinned_byte_for_byte() {
    // Findings arrive out of order (file b first) and with a duplicate;
    // the report must sort by (file, line, rule, message) and dedup.
    let mut rep = Report::default();
    rep.findings.extend(check_source("src/b.rs", "let x = a.partial_cmp(&b).unwrap();\n"));
    rep.findings.extend(check_source(
        "src/a.rs",
        "use std::time::Instant;\nuse std::collections::HashMap;\n",
    ));
    rep.findings.extend(check_source("src/b.rs", "let x = a.partial_cmp(&b).unwrap();\n"));
    rep.files_scanned = 2;
    rep.finalize();
    let expected = "\
src/a.rs:1 D1 wall-clock `Instant` outside the telemetry allowlist (util/logging, util/benchkit, engine/grpo); time must not influence search results
src/a.rs:2 D2 hash-ordered `HashMap`: iteration order can feed ordered logic; use BTreeMap/BTreeSet, sort-after-collect, or justify with an allow
src/b.rs:1 D3 NaN-unsafe comparator `.partial_cmp(..).unwrap()`; use util::ford::cmp_f64 (total order)
detlint: 3 findings in 2 files
";
    assert_eq!(rep.render(), expected);
}

#[test]
fn clean_report_is_a_single_line() {
    let mut rep = Report::default();
    rep.files_scanned = 3;
    rep.finalize();
    assert_eq!(rep.render(), "detlint: 3 files, no findings\n");
}

// ---- --fix-allow --------------------------------------------------------

#[test]
fn fix_allow_strips_stale_directives() {
    let dir = std::env::temp_dir().join(format!("detlint_fix_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let file = dir.join("stale.rs");
    std::fs::write(
        &file,
        "let x = 1; // detlint:allow(D2): stale trailing\n// detlint:allow(D1): stale standalone\nlet y = 2;\n",
    )
    .unwrap();
    let paths = vec![file.clone()];
    assert_eq!(run_paths(&paths).unwrap().findings.len(), 2, "both directives stale");
    let fixed = fix_unused_allows(&paths).unwrap();
    assert_eq!(fixed, 2);
    assert_eq!(std::fs::read_to_string(&file).unwrap(), "let x = 1;\nlet y = 2;\n");
    assert!(run_paths(&paths).unwrap().is_clean());
    std::fs::remove_dir_all(&dir).ok();
}

// ---- the gate: the shipped tree is lint-clean ---------------------------

#[test]
fn shipped_tree_is_lint_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let paths: Vec<PathBuf> = ["src", "tests", "benches"]
        .iter()
        .map(|d| root.join(d))
        .filter(|p| p.is_dir())
        .collect();
    assert_eq!(paths.len(), 3, "expected src/, tests/ and benches/ under {root:?}");
    let rep = run_paths(&paths).unwrap();
    assert!(
        rep.is_clean(),
        "the shipped tree must pass its own lint:\n{}",
        rep.render()
    );
    assert!(rep.files_scanned > 40, "walker saw only {} files", rep.files_scanned);
}

#[test]
fn rule_registry_is_complete() {
    let ids: Vec<&str> = hetrl::lint::RULES.iter().map(|(r, _)| r.id()).collect();
    assert_eq!(ids, vec!["D1", "D2", "D3", "D4", "D5", "A0"]);
    for (r, summary) in hetrl::lint::RULES {
        assert!(!summary.is_empty(), "{} needs a summary", r.id());
    }
    // Suppressible rules round-trip through the directive parser; the
    // meta rule does not.
    for id in ["D1", "D2", "D3", "D4", "D5"] {
        assert_eq!(Rule::parse_allowable(id).map(Rule::id), Some(id));
    }
    assert!(Rule::parse_allowable("A0").is_none());
}
