//! Properties of the asynchronous workflow model (`crate::asyncrl`,
//! `hetrl replay --workflow async`):
//!
//! * **the staleness bound is hard** — in every replayed trace, under
//!   every policy, the observed off-policy staleness never exceeds the
//!   configured bound `k`, and the rollout queue never exceeds its
//!   capacity. The bound is structural (dependency edges in the DES op
//!   graph), so noise and fleet churn cannot break it;
//! * **`k = 0` degenerates to the synchronous path bit-identically** —
//!   an async replay with staleness bound 0 delegates to
//!   [`hetrl::elastic::replay`] with the workflow forced to sync, so
//!   the results are equal as values, at every thread count;
//! * **bit-determinism across thread counts** — the pool-split search
//!   and the async replay run on the same engine contract as the sync
//!   stack: the deterministic projection (everything except cache
//!   hit/miss telemetry) is identical at 1, 2 and 8 worker threads;
//! * **all five policies run** — static, warm-replan, anytime, preempt
//!   and oracle all complete on a seeded async trace with finite,
//!   positive goodput.

use hetrl::asyncrl::{replay_async, AsyncReplayResult};
use hetrl::elastic::{replay, Policy, ReplayResult};
use hetrl::testing::fixtures;
use hetrl::topology::Scenario;
use hetrl::workflow::Mode;

/// The deterministic projection of a replay: everything except the
/// cache hit/miss telemetry, which is approximate when threads > 1.
#[allow(clippy::type_complexity)]
fn fingerprint(
    r: &ReplayResult,
) -> Vec<(usize, Vec<String>, bool, usize, usize, usize, u64, u64, usize, usize, u64)> {
    r.records
        .iter()
        .map(|x| {
            (
                x.iter,
                x.events.clone(),
                x.replanned,
                x.evals,
                x.anytime_evals,
                x.hypothesis_evals,
                x.migration_secs.to_bits(),
                x.iter_secs.to_bits(),
                x.samples,
                x.active_gpus,
                x.anytime_cost.to_bits(),
            )
        })
        .collect()
}

/// [`fingerprint`] plus the async-side telemetry (queue depths, stall,
/// staleness), all bit-exact.
fn async_fingerprint(
    r: &AsyncReplayResult,
) -> (
    Vec<(usize, Vec<String>, bool, usize, usize, usize, u64, u64, usize, usize, u64)>,
    Vec<(u64, usize, u64, usize)>,
    usize,
) {
    (
        fingerprint(&r.base),
        r.queue
            .iter()
            .map(|q| {
                (
                    q.queue_depth_mean.to_bits(),
                    q.queue_depth_max,
                    q.producer_stall_secs.to_bits(),
                    q.max_staleness,
                )
            })
            .collect(),
        r.max_staleness,
    )
}

#[test]
fn staleness_bound_never_exceeded_in_any_replay() {
    let wf = fixtures::tiny_wf();
    let job = fixtures::async_job();
    for k in [1usize, 2] {
        for policy in [Policy::Static, Policy::Warm, Policy::Anytime] {
            for seed in [3u64, 9] {
                let cfg = fixtures::async_replay_cfg(k, 1);
                let r = replay_async(
                    Scenario::MultiCountry,
                    &fixtures::small_spec(),
                    &wf,
                    &job,
                    policy,
                    &cfg,
                    seed,
                );
                assert!(
                    r.max_staleness <= k,
                    "staleness {} > bound {k} ({policy:?}, seed {seed})",
                    r.max_staleness
                );
                for (i, q) in r.queue.iter().enumerate() {
                    assert!(q.max_staleness <= k, "iter {i}");
                    assert!(
                        q.queue_depth_max <= cfg.queue_capacity,
                        "iter {i}: depth {} > cap {}",
                        q.queue_depth_max,
                        cfg.queue_capacity
                    );
                }
            }
        }
    }
}

#[test]
fn k0_replay_is_bit_identical_to_the_sync_path() {
    let wf = fixtures::tiny_wf();
    let job = fixtures::async_job();
    for seed in [1u64, 5, 11] {
        // The 1-thread runs must be equal as whole values (cache
        // telemetry included); at higher thread counts compare the
        // deterministic projection.
        let a1 = replay_async(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Anytime,
            &fixtures::async_replay_cfg(0, 1),
            seed,
        );
        let s1 = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf.with_mode(Mode::Sync),
            &job,
            Policy::Anytime,
            &fixtures::async_replay_cfg(0, 1).base,
            seed,
        );
        assert_eq!(a1.base, s1, "seed {seed}");
        assert_eq!(a1.max_staleness, 0);
        assert_eq!(a1.workflow_name(), "sync");
        for threads in fixtures::test_threads() {
            let a = replay_async(
                Scenario::MultiCountry,
                &fixtures::small_spec(),
                &wf,
                &job,
                Policy::Anytime,
                &fixtures::async_replay_cfg(0, threads),
                seed,
            );
            let s = replay(
                Scenario::MultiCountry,
                &fixtures::small_spec(),
                &wf.with_mode(Mode::Sync),
                &job,
                Policy::Anytime,
                &fixtures::async_replay_cfg(0, threads).base,
                seed,
            );
            assert_eq!(fingerprint(&a.base), fingerprint(&s), "seed {seed} threads {threads}");
            // And the k=0 projection is thread-count independent.
            assert_eq!(fingerprint(&a.base), fingerprint(&a1.base), "threads {threads}");
        }
    }
}

#[test]
fn async_replay_bit_identical_across_thread_counts() {
    let wf = fixtures::tiny_wf();
    let job = fixtures::async_job();
    for seed in [2u64, 7, 13] {
        let base = replay_async(
            Scenario::MultiRegionHybrid,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Anytime,
            &fixtures::async_replay_cfg(2, 1),
            seed,
        );
        for threads in fixtures::test_threads() {
            let r = replay_async(
                Scenario::MultiRegionHybrid,
                &fixtures::small_spec(),
                &wf,
                &job,
                Policy::Anytime,
                &fixtures::async_replay_cfg(2, threads),
                seed,
            );
            assert_eq!(
                async_fingerprint(&r),
                async_fingerprint(&base),
                "seed {seed} threads {threads}"
            );
        }
    }
}

#[test]
fn all_five_policies_complete_on_an_async_trace() {
    let wf = fixtures::tiny_wf();
    let job = fixtures::async_job();
    for policy in Policy::ALL {
        let r = replay_async(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            policy,
            &fixtures::async_replay_cfg(2, 1),
            3,
        );
        assert_eq!(r.base.records.len(), r.queue.len(), "{policy:?}");
        assert!(r.base.total_secs > 0.0 && r.base.total_secs.is_finite(), "{policy:?}");
        assert!(r.base.throughput() > 0.0, "{policy:?}");
        assert_eq!(r.workflow_name(), "async", "{policy:?}");
        if !policy.runs_background() {
            assert_eq!(r.base.anytime_evals, 0, "{policy:?}");
        }
    }
}
