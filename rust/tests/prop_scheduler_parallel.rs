//! Properties of the parallel plan-evaluation engine:
//!
//! * **determinism across thread counts** — the same seed produces the
//!   bit-identical best cost, best plan and eval count at 1, 2 and 8
//!   worker threads (the engine's core contract: quotas are derived at
//!   barriers and merges are ordered by arm index, so the schedule of
//!   evaluated candidates never depends on thread interleaving);
//! * **hard budget cap** — parallel runs never exceed `Budget::evals`
//!   (per-rung quotas sum to at most the remaining budget);
//! * the always-on cost cache changes nothing: the reported best cost
//!   equals a fresh, uncached cost-model evaluation of the best plan;
//! * the warm replanner picks the identical plan at any thread count.

use hetrl::costmodel::CostModel;
use hetrl::elastic::{plan_to_base, ClusterEvent, FleetState, ReplanConfig, Replanner};
use hetrl::scheduler::{Budget, PureEaScheduler, ScheduleOutcome, Scheduler, ShaEaScheduler};
use hetrl::testing::fixtures;
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::workflow::{JobConfig, RlWorkflow};

fn env(scenario: Scenario) -> (RlWorkflow, hetrl::topology::DeviceTopology, JobConfig) {
    fixtures::env(scenario)
}

fn sha(seed: u64, threads: usize, budget: usize, scenario: Scenario) -> ScheduleOutcome {
    let (wf, topo, job) = env(scenario);
    ShaEaScheduler::with_threads(seed, threads).schedule(&topo, &wf, &job, Budget::evals(budget))
}

#[test]
fn sha_bit_identical_across_thread_counts() {
    for seed in [1u64, 7] {
        let base = sha(seed, 1, 300, Scenario::MultiCountry);
        assert!(base.cost.is_finite(), "seed {seed}: no plan at 1 thread");
        for threads in fixtures::test_threads().into_iter().filter(|&t| t != 1) {
            let out = sha(seed, threads, 300, Scenario::MultiCountry);
            assert_eq!(
                out.cost.to_bits(),
                base.cost.to_bits(),
                "seed {seed}: best cost at {threads} threads ({}) != 1 thread ({})",
                out.cost,
                base.cost
            );
            assert_eq!(
                out.plan, base.plan,
                "seed {seed}: best plan differs at {threads} threads"
            );
            assert_eq!(
                out.evals, base.evals,
                "seed {seed}: eval count differs at {threads} threads"
            );
        }
    }
}

#[test]
fn parallel_runs_never_exceed_budget() {
    for threads in [1usize, 2, 8] {
        for budget in [50usize, 400] {
            let out = sha(3, threads, budget, Scenario::SingleRegion);
            assert!(
                out.evals <= budget,
                "{threads} threads overran budget {budget}: {}",
                out.evals
            );
        }
    }
    let (wf, topo, job) = env(Scenario::SingleRegion);
    let mut ea = PureEaScheduler::new(5);
    ea.threads = 4;
    let out = ea.schedule(&topo, &wf, &job, Budget::evals(150));
    assert!(out.evals <= 150, "pure EA overran: {}", out.evals);
}

#[test]
fn cached_best_cost_matches_fresh_evaluation() {
    let out = sha(11, 4, 250, Scenario::MultiRegionHybrid);
    let (wf, topo, job) = env(Scenario::MultiRegionHybrid);
    let plan = out.plan.expect("plan");
    let fresh = CostModel::new(&topo, &wf, &job).plan_cost(&plan).iter_time;
    assert_eq!(
        fresh.to_bits(),
        out.cost.to_bits(),
        "cache must be transparent: fresh {fresh} vs reported {}",
        out.cost
    );
    assert!(out.cache_misses > 0);
}

#[test]
fn warm_replan_identical_across_thread_counts() {
    let (wf, _, _) = fixtures::env(Scenario::MultiCountry);
    let job = JobConfig::tiny();
    let run = |threads: usize| {
        let mut fleet = FleetState::new(build_testbed(
            Scenario::MultiCountry,
            &TestbedSpec::default(),
        ));
        let cfg = ReplanConfig {
            warm_budget: 80,
            cold_budget: 150,
            seed_mutants: 3,
            threads,
            ..ReplanConfig::default()
        };
        let mut rp = Replanner::new(21, cfg);
        let (topo0, map0) = fleet.snapshot();
        let base = plan_to_base(&rp.cold_plan(&topo0, &wf, &job).plan.expect("cold"), &map0);
        fleet.apply(&ClusterEvent::MachinePreempt { machine: 2 });
        let (topo1, map1) = fleet.snapshot();
        let b2n = FleetState::base_to_snapshot(&map1);
        rp.replan(&topo1, &wf, &job, &base, &b2n)
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.plan, b.plan, "warm replan plan differs across thread counts");
    assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    assert_eq!(a.evals, b.evals);
    assert_eq!(a.migration_secs.to_bits(), b.migration_secs.to_bits());
}

/// Regression for the detlint D1 finding: the eval ledger used to treat
/// `Budget::wall_secs` as a second exhaustion condition, so machine
/// load (or an aggressive cap) could cut a seeded search short and
/// change the selected plan. Since the fix, wall-clock is telemetry
/// only: an absurdly tight wall cap must yield the bit-identical
/// outcome of the pure eval budget.
#[test]
fn wall_cap_is_telemetry_only() {
    let (wf, topo, job) = env(Scenario::MultiCountry);
    for threads in fixtures::test_threads() {
        let base = ShaEaScheduler::with_threads(9, threads)
            .schedule(&topo, &wf, &job, Budget::evals(250));
        let tight = ShaEaScheduler::with_threads(9, threads)
            .schedule(&topo, &wf, &job, Budget::timed(250, 1e-12));
        assert!(base.cost.is_finite(), "no plan at {threads} threads");
        assert_eq!(
            tight.plan, base.plan,
            "{threads} threads: a wall cap changed the selected plan"
        );
        assert_eq!(tight.cost.to_bits(), base.cost.to_bits());
        assert_eq!(
            tight.evals, base.evals,
            "{threads} threads: a wall cap changed the eval count"
        );
    }
}

/// Back-to-back runs at the same seed are bit-identical even though
/// their wall-clock telemetry differs — plan selection must depend on
/// nothing the ledger's stopwatch measures.
#[test]
fn repeat_runs_bit_identical_despite_wall_jitter() {
    let a = sha(13, 2, 200, Scenario::SingleRegion);
    let b = sha(13, 2, 200, Scenario::SingleRegion);
    assert_eq!(a.plan, b.plan);
    assert_eq!(a.cost.to_bits(), b.cost.to_bits());
    assert_eq!(a.evals, b.evals);
}
