//! Properties of the failure-and-recovery model (`hetrl replay
//! --faults`, `ReplayConfig::recovery`):
//!
//! * **bit-determinism under faults** — a chaos replay (seeded
//!   transient faults, recovery pricing on, the checkpoint interval
//!   searched) is bit-identical at 1, 2 and 8 worker threads, across
//!   seeds;
//! * **the degeneracy pin** — with a loss-free trace (all machine
//!   losses noticed, no faults) and checkpointing disabled, enabling
//!   recovery charges exactly `0.0` everywhere: the result equals the
//!   recovery-disabled replay *as a value*, for every policy, in both
//!   the sync and async workflows;
//! * **rollback is bounded by the cadence** — while the checkpoint
//!   store is up, no single rollback ever reworks more than one
//!   checkpoint interval of productive time;
//! * **retry stalls are bounded** — total stall never exceeds
//!   `faults × max_retries × backoff`, and a zero-retry policy charges
//!   no stall at all (NIC bursts degenerate to plain degrade events);
//! * **total fleet loss degrades gracefully** — a trace that preempts
//!   *every* machine at once must not panic under any policy, in either
//!   workflow: the replay stalls in a degraded state, retains the
//!   incumbent, and resumes (and finishes productive iterations) after
//!   the machines rejoin.

use hetrl::asyncrl::replay_async_with_trace;
use hetrl::costmodel::RecoveryModel;
use hetrl::elastic::{
    generate_trace, replay, replay_with_trace, CkptSearchConfig, ClusterEvent, Policy,
    ReplayResult, TraceEvent,
};
use hetrl::testing::fixtures;
use hetrl::topology::Scenario;
use hetrl::workflow::JobConfig;

/// The deterministic projection of a replay: everything except the
/// cache hit/miss telemetry, which is approximate when threads > 1.
#[allow(clippy::type_complexity)]
fn fingerprint(
    r: &ReplayResult,
) -> (
    Vec<(usize, Vec<String>, bool, usize, u64, u64, usize, usize, u64, u64, u64, bool)>,
    (u64, u64, u64, u64, usize, usize, u64, usize),
) {
    let records = r
        .records
        .iter()
        .map(|x| {
            (
                x.iter,
                x.events.clone(),
                x.replanned,
                x.evals,
                x.migration_secs.to_bits(),
                x.iter_secs.to_bits(),
                x.samples,
                x.active_gpus,
                x.retry_stall_secs.to_bits(),
                x.rework_secs.to_bits(),
                x.ckpt_secs.to_bits(),
                x.degraded,
            )
        })
        .collect();
    let totals = (
        r.total_secs.to_bits(),
        r.retry_stall_secs.to_bits(),
        r.rework_secs.to_bits(),
        r.ckpt_secs.to_bits(),
        r.ckpts,
        r.degraded_iters,
        r.ckpt_interval_secs.to_bits(),
        r.total_evals,
    );
    (records, totals)
}

/// A trace that preempts every machine of the 3-machine small testbed
/// at once (unnoticed) and rejoins them all a few iterations later.
fn total_loss_trace() -> Vec<TraceEvent> {
    let mut trace: Vec<TraceEvent> = (0..3)
        .map(|m| TraceEvent {
            at_iter: 2,
            event: ClusterEvent::MachinePreempt { machine: m },
            notice_secs: None,
        })
        .collect();
    trace.extend((0..3).map(|m| TraceEvent {
        at_iter: 5,
        event: ClusterEvent::MachineJoin { machine: m },
        notice_secs: None,
    }));
    trace
}

#[test]
fn chaos_replay_is_bit_deterministic_across_threads() {
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    for seed in [3u64, 7, 13] {
        let mut base_cfg = fixtures::fault_replay_cfg(3, 1);
        // Exercise the searched checkpoint interval too: two candidate
        // cadences, one halving round.
        base_cfg.ckpt_search = Some(CkptSearchConfig {
            candidates: vec![120.0, 600.0],
            rounds: 1,
            ..CkptSearchConfig::default()
        });
        let base = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Warm,
            &base_cfg,
            seed,
        );
        assert!(base.retry_stall_secs > 0.0, "seed {seed}: chaos trace charged no stall");
        for threads in fixtures::test_threads() {
            let cfg = fixtures::fault_replay_cfg(3, threads);
            let cfg = hetrl::elastic::ReplayConfig { ckpt_search: base_cfg.ckpt_search.clone(), ..cfg };
            let r = replay(
                Scenario::MultiCountry,
                &fixtures::small_spec(),
                &wf,
                &job,
                Policy::Warm,
                &cfg,
                seed,
            );
            assert_eq!(
                fingerprint(&r),
                fingerprint(&base),
                "seed {seed}, threads {threads}: chaos replay diverged"
            );
        }
    }
}

#[test]
fn inert_recovery_is_the_disabled_replay_every_policy_both_workflows() {
    // Loss-free trace: every machine loss noticed, zero faults. With
    // checkpointing disabled too, recovery-enabled must equal
    // recovery-disabled as a value (every charge is exactly 0.0).
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    let mut cfg = fixtures::small_replay_cfg();
    cfg.trace.notice_override = Some(45.0);
    let mut inert = cfg.clone();
    inert.recovery = RecoveryModel::with_interval(0.0);
    for policy in Policy::ALL {
        let plain = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            policy,
            &cfg,
            17,
        );
        let rec = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            policy,
            &inert,
            17,
        );
        assert_eq!(plain, rec, "{policy:?}: inert recovery perturbed the sync replay");
        assert_eq!(rec.retry_stall_secs, 0.0);
        assert_eq!(rec.rework_secs, 0.0);
        assert_eq!(rec.ckpts, 0);
    }
    // Async workflow (k = 2), same pin.
    let ajob = fixtures::async_job();
    let mut acfg = fixtures::async_replay_cfg(2, 1);
    acfg.base.trace.notice_override = Some(45.0);
    let mut ainert = acfg.clone();
    ainert.base.recovery = RecoveryModel::with_interval(0.0);
    for policy in Policy::ALL {
        let topo = fixtures::small_topo(Scenario::MultiCountry);
        let trace = generate_trace(&topo, &acfg.base.trace, 17);
        let plain =
            replay_async_with_trace(topo.clone(), trace.clone(), &wf, &ajob, policy, &acfg, 17);
        let rec = replay_async_with_trace(topo, trace, &wf, &ajob, policy, &ainert, 17);
        assert_eq!(plain, rec, "{policy:?}: inert recovery perturbed the async replay");
    }
}

#[test]
fn rollback_never_exceeds_one_checkpoint_interval() {
    // Unnoticed losses only, store never down: every rollback reworks
    // strictly less than one interval of productive time.
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    let trace = vec![
        TraceEvent {
            at_iter: 3,
            event: ClusterEvent::MachinePreempt { machine: 1 },
            notice_secs: None,
        },
        TraceEvent {
            at_iter: 5,
            event: ClusterEvent::MachineJoin { machine: 1 },
            notice_secs: None,
        },
        TraceEvent {
            at_iter: 6,
            event: ClusterEvent::MachinePreempt { machine: 2 },
            notice_secs: None,
        },
    ];
    // Calibrate the cadence to the measured iteration time (half the
    // first iteration) so every iteration provably crosses at least one
    // cadence point, whatever the absolute time scale of the testbed.
    let mut cfg = fixtures::fault_replay_cfg(0, 1);
    let topo = fixtures::small_topo(Scenario::MultiCountry);
    let probe = {
        let mut free = cfg.clone();
        free.recovery = RecoveryModel::default(); // disabled
        replay_with_trace(topo.clone(), trace.clone(), &wf, &job, Policy::Warm, &free, 4)
    };
    let interval = probe.records[0].iter_secs / 2.0;
    assert!(interval > 0.0, "probe replay measured a zero-length iteration");
    cfg.recovery = RecoveryModel::with_interval(interval);
    let r = replay_with_trace(topo, trace, &wf, &job, Policy::Warm, &cfg, 4);
    assert!(r.rework_secs > 0.0, "unnoticed losses charged no rework");
    for rec in &r.records {
        assert!(
            rec.rework_secs <= interval + 1e-9,
            "iter {}: rollback {} exceeds the {interval}s cadence",
            rec.iter,
            rec.rework_secs
        );
    }
    assert!(r.ckpts > 0, "cadence never completed a checkpoint");
}

#[test]
fn retry_stalls_are_bounded_and_vanish_with_zero_retries() {
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    let faults = 4usize;
    for seed in [1u64, 2, 5] {
        let cfg = fixtures::fault_replay_cfg(faults, 1);
        let r = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Warm,
            &cfg,
            seed,
        );
        let bound = faults as f64 * cfg.recovery.max_stall_secs();
        assert!(
            r.retry_stall_secs <= bound + 1e-9,
            "seed {seed}: stall {} exceeds {faults} x {}",
            r.retry_stall_secs,
            cfg.recovery.max_stall_secs()
        );
        // Zero-retry policy: transient faults charge no stall at all —
        // a NIC burst degenerates to a plain bandwidth degradation.
        let mut zero = cfg.clone();
        zero.recovery.max_retries = 0;
        let rz = replay(
            Scenario::MultiCountry,
            &fixtures::small_spec(),
            &wf,
            &job,
            Policy::Warm,
            &zero,
            seed,
        );
        assert_eq!(rz.retry_stall_secs, 0.0, "seed {seed}: zero-retry policy stalled");
    }
}

#[test]
fn total_fleet_loss_degrades_and_resumes_sync() {
    let wf = fixtures::tiny_wf();
    let job = JobConfig::tiny();
    let cfg = fixtures::fault_replay_cfg(0, 1);
    for policy in Policy::ALL {
        let topo = fixtures::small_topo(Scenario::MultiCountry);
        let r = replay_with_trace(topo, total_loss_trace(), &wf, &job, policy, &cfg, 6);
        assert_eq!(r.records.len(), cfg.iters, "{policy:?}: replay did not finish");
        assert!(r.degraded_iters >= 1, "{policy:?}: total loss never degraded");
        assert!(r.total_secs.is_finite(), "{policy:?}");
        // Degraded iterations stall the whole fleet.
        for rec in r.records.iter().filter(|rec| rec.degraded) {
            assert_eq!(rec.samples, 0, "{policy:?}: degraded iter processed samples");
        }
        // After the join barrier the replay resumes and finishes
        // productive iterations.
        let last = r.records.last().unwrap();
        assert!(!last.degraded, "{policy:?}: never resumed after the fleet rejoined");
        assert!(last.samples > 0, "{policy:?}: resumed but processed nothing");
    }
}

#[test]
fn total_fleet_loss_degrades_and_resumes_async() {
    let wf = fixtures::tiny_wf();
    let job = fixtures::async_job();
    let mut cfg = fixtures::async_replay_cfg(2, 1);
    cfg.base.iters = 8;
    cfg.base.recovery = RecoveryModel::with_interval(120.0);
    for policy in [Policy::Static, Policy::Warm, Policy::Preempt] {
        let topo = fixtures::small_topo(Scenario::MultiCountry);
        let r = replay_async_with_trace(topo, total_loss_trace(), &wf, &job, policy, &cfg, 6);
        assert_eq!(r.base.records.len(), cfg.base.iters, "{policy:?}");
        assert!(r.base.degraded_iters >= 1, "{policy:?}: total loss never degraded");
        assert!(r.base.total_secs.is_finite(), "{policy:?}");
        let last = r.base.records.last().unwrap();
        assert!(!last.degraded, "{policy:?}: async replay never resumed");
        assert!(last.samples > 0, "{policy:?}: resumed but processed nothing");
    }
}
