//! Integration: cost model vs discrete-event simulator agreement, plus
//! the component-engine equivalence pins (the engine behind
//! `SimGraph::simulate` must reproduce the legacy executor
//! `SimGraph::simulate_reference` bit-identically).

use hetrl::balance::{self, BalanceConfig};
use hetrl::costmodel::CostModel;
use hetrl::scheduler::{Budget, Scheduler, ShaEaScheduler};
use hetrl::simulator::{simulate_plan, NoiseModel, OpId, SimConfig, SimGraph};
use hetrl::testing::fixtures;
use hetrl::topology::Scenario;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec};

/// Bit-exact equivalence of the component engine and the pinned
/// pre-component reference executor on one graph: makespan and the
/// full start/finish/busy vectors must match to the last bit (`==` on
/// f64 — no tolerance; a completed run contains no NaNs).
fn assert_engine_equivalence(g: &SimGraph, label: &str) {
    let c = g.simulate();
    let r = g.simulate_reference();
    assert_eq!(c.makespan, r.makespan, "{label}: makespan diverged");
    assert_eq!(c.start, r.start, "{label}: start vector diverged");
    assert_eq!(c.finish, r.finish, "{label}: finish vector diverged");
    assert_eq!(c.busy, r.busy, "{label}: busy vector diverged");
}

/// The unit graphs from `simulator::des`'s own test suite, rebuilt
/// here so the equivalence pin covers every hand-written shape the
/// executor is specified against.
fn unit_graphs() -> Vec<(&'static str, SimGraph)> {
    let mut graphs = Vec::new();

    let mut g = SimGraph::new(1);
    let a = g.add(vec![0], 1.0, vec![], 0);
    let b = g.add(vec![0], 2.0, vec![a], 0);
    g.add(vec![0], 3.0, vec![b], 0);
    graphs.push(("sequential_chain", g));

    let mut g = SimGraph::new(2);
    g.add(vec![0], 5.0, vec![], 0);
    g.add(vec![1], 3.0, vec![], 1);
    graphs.push(("parallel_on_disjoint_resources", g));

    let mut g = SimGraph::new(1);
    g.add(vec![0], 5.0, vec![], 0);
    g.add(vec![0], 3.0, vec![], 1);
    graphs.push(("contention_serializes", g));

    let mut g = SimGraph::new(2);
    g.add(vec![0], 4.0, vec![], 0);
    g.add(vec![1], 1.0, vec![], 0);
    g.add(vec![0, 1], 1.0, vec![], 1);
    graphs.push(("multi_resource_op_waits_for_all", g));

    let mut g = SimGraph::new(2);
    let a = g.add(vec![0], 2.0, vec![], 0);
    g.add(vec![1], 1.0, vec![a], 0);
    graphs.push(("dependencies_respected_across_resources", g));

    let mut g = SimGraph::new(2);
    let mut prev_stage: Vec<Option<OpId>> = vec![None, None];
    for _m in 0..3 {
        let f0 = g.add(vec![0], 1.0, prev_stage[0].into_iter().collect(), 0);
        let f1 = g.add(vec![1], 1.0, vec![f0], 0);
        prev_stage = vec![Some(f0), Some(f1)];
    }
    graphs.push(("pipeline_bubble_emerges", g));

    let mut g = SimGraph::new(1);
    let a = g.add(vec![0], 1.5, vec![], 7);
    g.barrier(vec![a]);
    graphs.push(("barrier_and_tags", g));

    let mut g = SimGraph::new(4);
    let mut last = Vec::new();
    for i in 0..50 {
        let deps = if i % 7 == 0 { last.clone() } else { Vec::new() };
        let id = g.add(vec![i % 4], (i % 5) as f64 * 0.3 + 0.1, deps, 0);
        if i % 3 == 0 {
            last = vec![id];
        }
    }
    graphs.push(("deterministic_50_op_graph", g));

    graphs
}

#[test]
fn component_engine_matches_reference_on_unit_graphs() {
    for (label, g) in unit_graphs() {
        assert_engine_equivalence(&g, label);
    }
}

#[test]
fn component_engine_matches_reference_on_random_dags() {
    // 16 seeded random DAGs (mixed device/link-token resources,
    // quantized durations so ready-time ties genuinely occur,
    // barriers) through the shared fixture builder.
    for seed in 0..16u64 {
        let g = fixtures::random_sim_graph(seed, 120, 5);
        assert_engine_equivalence(&g, &format!("random_sim_graph(seed {seed})"));
    }
}

#[test]
fn component_engine_empty_graph() {
    let g = SimGraph::new(3);
    let o = g.simulate();
    assert_eq!(o.makespan, 0.0);
    assert!(o.start.is_empty() && o.finish.is_empty());
    assert_eq!(o.busy, vec![0.0; 3]);
    assert_engine_equivalence(&g, "empty graph");
}

#[test]
fn cost_model_ranks_like_simulator() {
    // Over a set of random valid plans, cost-model and simulator
    // orderings must correlate strongly — this is the property that
    // makes cost-model-driven search meaningful.
    let (wf, topo, job) = fixtures::env(Scenario::MultiCountry);
    let cm = CostModel::new(&topo, &wf, &job);
    let mut pred = Vec::new();
    let mut meas = Vec::new();
    let mut tries = 0;
    while pred.len() < 10 && tries < 100 {
        tries += 1;
        let Some(plan) = fixtures::random_plan(&wf, &topo, &job, 1700 + tries as u64) else {
            continue;
        };
        pred.push(cm.plan_cost(&plan).iter_time);
        let cfg = SimConfig { iters: 2, seed: 9, noise: NoiseModel::default(), shuffle: None };
        meas.push(simulate_plan(&topo, &wf, &job, &plan, &cfg).iter_time);
    }
    assert!(pred.len() >= 6, "not enough valid plans generated");
    let corr = hetrl::util::stats::pearson(&pred, &meas);
    assert!(
        corr > 0.6,
        "cost model vs simulator correlation {corr} too weak\npred {pred:?}\nmeas {meas:?}"
    );
}

#[test]
fn balancing_does_not_hurt_simulation() {
    let (wf, topo, job) =
        fixtures::env_with(Scenario::MultiRegionHybrid, Algo::Grpo, Mode::Sync, ModelSpec::qwen_8b());
    let out = ShaEaScheduler::new(7).schedule(&topo, &wf, &job, Budget::timed(400, 40.0));
    let plan = out.plan.unwrap();
    let balanced = balance::apply(&plan, &wf, &topo, BalanceConfig::default());
    let cfg = SimConfig { iters: 3, seed: 5, noise: NoiseModel::off(), shuffle: None };
    let off = simulate_plan(&topo, &wf, &job, &plan, &cfg).iter_time;
    let on = simulate_plan(&topo, &wf, &job, &balanced, &cfg).iter_time;
    assert!(on <= off * 1.05, "balancing hurt simulation: {on} vs {off}");
}

#[test]
fn scenario_ordering_preserved_in_simulation() {
    // The same plan gets slower as the network gets more heterogeneous.
    let (wf, topo1, _) = fixtures::env(Scenario::SingleRegion);
    let job = JobConfig::tiny();
    let out = ShaEaScheduler::new(1).schedule(&topo1, &wf, &job, Budget::timed(150, 20.0));
    let plan = out.plan.unwrap();
    let cfg = SimConfig { iters: 2, seed: 2, noise: NoiseModel::off(), shuffle: None };
    let t1 = simulate_plan(&topo1, &wf, &job, &plan, &cfg).iter_time;
    let (_, topo4, _) = fixtures::env(Scenario::MultiContinent);
    if plan.validate(&wf, &topo4, &job).is_ok() {
        let t4 = simulate_plan(&topo4, &wf, &job, &plan, &cfg).iter_time;
        assert!(t4 >= t1 * 0.99, "WAN should not be faster: {t4} vs {t1}");
    }
}

#[test]
fn utilization_sane_across_scenarios() {
    for scenario in [Scenario::SingleRegion, Scenario::MultiCountry] {
        let (wf, topo, _) =
            fixtures::env_with(scenario, Algo::Ppo, Mode::Sync, ModelSpec::qwen_4b());
        let job = JobConfig::tiny();
        let out = ShaEaScheduler::new(5).schedule(&topo, &wf, &job, Budget::timed(200, 30.0));
        let plan = out.plan.unwrap();
        let r = simulate_plan(&topo, &wf, &job, &plan, &SimConfig::default());
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        assert!(r.iter_time > 0.0 && r.iter_time.is_finite());
        assert_eq!(r.per_task.len(), wf.n_tasks());
    }
}
