//! Integration: cost model vs discrete-event simulator agreement.

use hetrl::balance::{self, BalanceConfig};
use hetrl::costmodel::CostModel;
use hetrl::scheduler::{Budget, Scheduler, ShaEaScheduler};
use hetrl::simulator::{simulate_plan, NoiseModel, SimConfig};
use hetrl::testing::fixtures;
use hetrl::topology::Scenario;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec};

#[test]
fn cost_model_ranks_like_simulator() {
    // Over a set of random valid plans, cost-model and simulator
    // orderings must correlate strongly — this is the property that
    // makes cost-model-driven search meaningful.
    let (wf, topo, job) = fixtures::env(Scenario::MultiCountry);
    let cm = CostModel::new(&topo, &wf, &job);
    let mut pred = Vec::new();
    let mut meas = Vec::new();
    let mut tries = 0;
    while pred.len() < 10 && tries < 100 {
        tries += 1;
        let Some(plan) = fixtures::random_plan(&wf, &topo, &job, 1700 + tries as u64) else {
            continue;
        };
        pred.push(cm.plan_cost(&plan).iter_time);
        let cfg = SimConfig { iters: 2, seed: 9, noise: NoiseModel::default() };
        meas.push(simulate_plan(&topo, &wf, &job, &plan, &cfg).iter_time);
    }
    assert!(pred.len() >= 6, "not enough valid plans generated");
    let corr = hetrl::util::stats::pearson(&pred, &meas);
    assert!(
        corr > 0.6,
        "cost model vs simulator correlation {corr} too weak\npred {pred:?}\nmeas {meas:?}"
    );
}

#[test]
fn balancing_does_not_hurt_simulation() {
    let (wf, topo, job) =
        fixtures::env_with(Scenario::MultiRegionHybrid, Algo::Grpo, Mode::Sync, ModelSpec::qwen_8b());
    let out = ShaEaScheduler::new(7).schedule(&topo, &wf, &job, Budget::timed(400, 40.0));
    let plan = out.plan.unwrap();
    let balanced = balance::apply(&plan, &wf, &topo, BalanceConfig::default());
    let cfg = SimConfig { iters: 3, seed: 5, noise: NoiseModel::off() };
    let off = simulate_plan(&topo, &wf, &job, &plan, &cfg).iter_time;
    let on = simulate_plan(&topo, &wf, &job, &balanced, &cfg).iter_time;
    assert!(on <= off * 1.05, "balancing hurt simulation: {on} vs {off}");
}

#[test]
fn scenario_ordering_preserved_in_simulation() {
    // The same plan gets slower as the network gets more heterogeneous.
    let (wf, topo1, _) = fixtures::env(Scenario::SingleRegion);
    let job = JobConfig::tiny();
    let out = ShaEaScheduler::new(1).schedule(&topo1, &wf, &job, Budget::timed(150, 20.0));
    let plan = out.plan.unwrap();
    let cfg = SimConfig { iters: 2, seed: 2, noise: NoiseModel::off() };
    let t1 = simulate_plan(&topo1, &wf, &job, &plan, &cfg).iter_time;
    let (_, topo4, _) = fixtures::env(Scenario::MultiContinent);
    if plan.validate(&wf, &topo4, &job).is_ok() {
        let t4 = simulate_plan(&topo4, &wf, &job, &plan, &cfg).iter_time;
        assert!(t4 >= t1 * 0.99, "WAN should not be faster: {t4} vs {t1}");
    }
}

#[test]
fn utilization_sane_across_scenarios() {
    for scenario in [Scenario::SingleRegion, Scenario::MultiCountry] {
        let (wf, topo, _) =
            fixtures::env_with(scenario, Algo::Ppo, Mode::Sync, ModelSpec::qwen_4b());
        let job = JobConfig::tiny();
        let out = ShaEaScheduler::new(5).schedule(&topo, &wf, &job, Budget::timed(200, 30.0));
        let plan = out.plan.unwrap();
        let r = simulate_plan(&topo, &wf, &job, &plan, &SimConfig::default());
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        assert!(r.iter_time > 0.0 && r.iter_time.is_finite());
        assert_eq!(r.per_task.len(), wf.n_tasks());
    }
}
