//! Integration: cost model vs discrete-event simulator agreement.

use hetrl::balance::{self, BalanceConfig};
use hetrl::costmodel::CostModel;
use hetrl::scheduler::levels::{
    assemble, assign_devices, default_task_plans, gpu_groupings, set_partitions,
};
use hetrl::scheduler::{Budget, Scheduler, ShaEaScheduler};
use hetrl::simulator::{simulate_plan, NoiseModel, SimConfig};
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::rng::Rng;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

#[test]
fn cost_model_ranks_like_simulator() {
    // Over a set of random valid plans, cost-model and simulator
    // orderings must correlate strongly — this is the property that
    // makes cost-model-driven search meaningful.
    let topo = build_testbed(Scenario::MultiCountry, &TestbedSpec::default());
    let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
    let job = JobConfig::default();
    let cm = CostModel::new(&topo, &wf, &job);
    let mut rng = Rng::new(17);
    let groupings = set_partitions(wf.n_tasks());
    let mut pred = Vec::new();
    let mut meas = Vec::new();
    let mut tries = 0;
    while pred.len() < 8 && tries < 80 {
        tries += 1;
        let tg = groupings[rng.below(groupings.len())].clone();
        let ggs = gpu_groupings(&wf, &job, &topo, &tg, 8);
        if ggs.is_empty() {
            continue;
        }
        let sizes = ggs[rng.below(ggs.len())].clone();
        let groups = assign_devices(&wf, &tg, &sizes, &topo, &mut rng);
        let Some(plans) = default_task_plans(&wf, &job, &topo, &tg, &groups, &mut rng, true)
        else {
            continue;
        };
        let plan = assemble(&tg, groups, plans);
        if plan.validate(&wf, &topo, &job).is_err() {
            continue;
        }
        pred.push(cm.plan_cost(&plan).iter_time);
        let cfg = SimConfig { iters: 2, seed: 9, noise: NoiseModel::default() };
        meas.push(simulate_plan(&topo, &wf, &job, &plan, &cfg).iter_time);
    }
    assert!(pred.len() >= 6, "not enough valid plans generated");
    let corr = hetrl::util::stats::pearson(&pred, &meas);
    assert!(
        corr > 0.6,
        "cost model vs simulator correlation {corr} too weak\npred {pred:?}\nmeas {meas:?}"
    );
}

#[test]
fn balancing_does_not_hurt_simulation() {
    let topo = build_testbed(Scenario::MultiRegionHybrid, &TestbedSpec::default());
    let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_8b());
    let job = JobConfig::default();
    let out = ShaEaScheduler::new(7).schedule(&topo, &wf, &job, Budget::timed(400, 40.0));
    let plan = out.plan.unwrap();
    let balanced = balance::apply(&plan, &wf, &topo, BalanceConfig::default());
    let cfg = SimConfig { iters: 3, seed: 5, noise: NoiseModel::off() };
    let off = simulate_plan(&topo, &wf, &job, &plan, &cfg).iter_time;
    let on = simulate_plan(&topo, &wf, &job, &balanced, &cfg).iter_time;
    assert!(on <= off * 1.05, "balancing hurt simulation: {on} vs {off}");
}

#[test]
fn scenario_ordering_preserved_in_simulation() {
    // The same plan gets slower as the network gets more heterogeneous.
    let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
    let job = JobConfig::tiny();
    let spec = TestbedSpec::default();
    let topo1 = build_testbed(Scenario::SingleRegion, &spec);
    let out = ShaEaScheduler::new(1).schedule(&topo1, &wf, &job, Budget::timed(150, 20.0));
    let plan = out.plan.unwrap();
    let cfg = SimConfig { iters: 2, seed: 2, noise: NoiseModel::off() };
    let t1 = simulate_plan(&topo1, &wf, &job, &plan, &cfg).iter_time;
    let topo4 = build_testbed(Scenario::MultiContinent, &spec);
    if plan.validate(&wf, &topo4, &job).is_ok() {
        let t4 = simulate_plan(&topo4, &wf, &job, &plan, &cfg).iter_time;
        assert!(t4 >= t1 * 0.99, "WAN should not be faster: {t4} vs {t1}");
    }
}

#[test]
fn utilization_sane_across_scenarios() {
    let wf = RlWorkflow::new(Algo::Ppo, Mode::Sync, ModelSpec::qwen_4b());
    let job = JobConfig::tiny();
    for scenario in [Scenario::SingleRegion, Scenario::MultiCountry] {
        let topo = build_testbed(scenario, &TestbedSpec::default());
        let out = ShaEaScheduler::new(5).schedule(&topo, &wf, &job, Budget::timed(200, 30.0));
        let plan = out.plan.unwrap();
        let r = simulate_plan(&topo, &wf, &job, &plan, &SimConfig::default());
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        assert!(r.iter_time > 0.0 && r.iter_time.is_finite());
        assert_eq!(r.per_task.len(), wf.n_tasks());
    }
}
