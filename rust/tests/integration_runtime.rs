//! Integration: PJRT runtime ↔ AOT artifacts numerics. These tests run
//! only when `artifacts/` exists (`make artifacts`).

use hetrl::runtime::{HostTensor, Runtime};
use hetrl::testing::fixtures;

fn runtime() -> Option<Runtime> {
    fixtures::artifacts_runtime()
}

#[test]
fn logprobs_consistent_with_forward() {
    // logprobs(tokens)[t] must equal log_softmax(forward(tokens))[t+1]
    // gathered at the next token — two different executables computing
    // the same math.
    let Some(rt) = runtime() else { return };
    let params = rt
        .execute("init", &[HostTensor::u32(vec![2], vec![0, 5])])
        .unwrap();
    let b = rt.manifest.batch;
    let l = rt.model().max_len;
    let v = rt.model().vocab;
    let tokens: Vec<i32> = (0..b * l).map(|i| ((i * 7 + 3) % 60) as i32 + 3).collect();

    let mut inputs = params.clone();
    inputs.push(HostTensor::i32(vec![b, l], tokens.clone()));
    let logits = rt.execute("forward", &inputs).unwrap()[0]
        .as_f32()
        .unwrap()
        .to_vec();

    let mut inputs = params.clone();
    inputs.push(HostTensor::i32(vec![b, l], tokens.clone()));
    let lp = rt.execute("logprobs", &inputs).unwrap()[0]
        .as_f32()
        .unwrap()
        .to_vec();

    for i in 0..b {
        for t in 0..l - 1 {
            let row = &logits[(i * l + t) * v..(i * l + t + 1) * v];
            let max = row.iter().cloned().fold(f32::MIN, f32::max);
            let lse: f32 = row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
            let want = row[tokens[i * l + t + 1] as usize] - lse;
            let got = lp[i * (l - 1) + t];
            assert!(
                (got - want).abs() < 2e-4,
                "mismatch at ({i},{t}): {got} vs {want}"
            );
        }
    }
}

#[test]
fn grpo_train_loss_matches_manual_formula_at_identity() {
    // With old == ref == current policy and advantage a, the token loss
    // reduces to -a per masked token (ratio = 1, KL = 0).
    let Some(rt) = runtime() else { return };
    let params = rt
        .execute("init", &[HostTensor::u32(vec![2], vec![0, 9])])
        .unwrap();
    let n_p = rt.manifest.n_params;
    let b = rt.manifest.batch;
    let l = rt.model().max_len;
    let tokens: Vec<i32> = (0..b * l).map(|i| ((i * 11 + 5) % 60) as i32 + 3).collect();
    let mut inputs = params.clone();
    inputs.push(HostTensor::i32(vec![b, l], tokens.clone()));
    let lp = rt.execute("logprobs", &inputs).unwrap()[0].clone();

    let zeros: Vec<HostTensor> = params
        .iter()
        .map(|p| HostTensor::f32(p.shape().to_vec(), vec![0.0; p.shape().iter().product()]))
        .collect();
    let adv: Vec<f32> = (0..b).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let mask = vec![1.0f32; b * (l - 1)];

    let mut inputs: Vec<HostTensor> = Vec::new();
    inputs.extend(params.clone());
    inputs.extend(zeros.clone());
    inputs.extend(zeros);
    inputs.push(HostTensor::scalar_f32(1.0));
    inputs.push(HostTensor::i32(vec![b, l], tokens));
    inputs.push(lp.clone());
    inputs.push(lp);
    inputs.push(HostTensor::f32(vec![b], adv.clone()));
    inputs.push(HostTensor::f32(vec![b, l - 1], mask));
    let out = rt.execute("grpo_train", &inputs).unwrap();
    let kl = out[3 * n_p + 1].as_f32().unwrap()[0];
    let loss = out[3 * n_p].as_f32().unwrap()[0];
    // mean over tokens of -adv (adv broadcast per row) = -mean(adv) = 0
    assert!(loss.abs() < 1e-4, "loss {loss}");
    assert!(kl.abs() < 1e-5, "kl {kl}");
    // updated params differ from inputs (gradient is nonzero per row)
    assert_ne!(out[2].as_f32().unwrap(), params[2].as_f32().unwrap());
}

#[test]
fn reward_and_value_heads_run() {
    let Some(rt) = runtime() else { return };
    let params = rt
        .execute("init", &[HostTensor::u32(vec![2], vec![1, 1])])
        .unwrap();
    let b = rt.manifest.batch;
    let l = rt.model().max_len;
    let tokens = HostTensor::i32(vec![b, l], vec![4; b * l]);
    let mut inputs = params.clone();
    inputs.push(tokens.clone());
    let score = rt.execute("reward", &inputs).unwrap();
    assert_eq!(score[0].shape(), &[b]);
    let mut inputs = params;
    inputs.push(tokens);
    let values = rt.execute("value", &inputs).unwrap();
    assert_eq!(values[0].shape(), &[b, l]);
}

#[test]
fn exec_counts_tracked() {
    let Some(rt) = runtime() else { return };
    let _ = rt
        .execute("init", &[HostTensor::u32(vec![2], vec![0, 0])])
        .unwrap();
    assert_eq!(*rt.exec_counts.borrow().get("init").unwrap(), 1);
}
