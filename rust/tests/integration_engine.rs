//! Integration: short real GRPO training runs through the full stack.
//! Requires `make artifacts`.

use hetrl::engine::{GrpoConfig, GrpoTrainer, TaskDifficulty, WorkerFleet};
use hetrl::runtime::Runtime;
use hetrl::testing::fixtures;

fn runtime() -> Option<Runtime> {
    fixtures::artifacts_runtime()
}

#[test]
fn five_steps_of_real_training() {
    let Some(rt) = runtime() else { return };
    let cfg = GrpoConfig {
        group_size: 4,
        max_new: 10,
        temperature: 1.0,
        difficulty: TaskDifficulty::Easy,
        seed: 3,
        expert_inject: true,
    };
    let mut trainer = GrpoTrainer::new(&rt, cfg, WorkerFleet::heterogeneous_default()).unwrap();
    let mut last_virtual = 0.0;
    for s in 0..5 {
        let st = trainer.step().unwrap();
        assert_eq!(st.step, s + 1);
        assert!(st.loss.is_finite());
        assert!(st.kl >= -1e-6, "KL must be ~nonnegative, got {}", st.kl);
        assert!((0.0..=1.0).contains(&st.mean_reward));
        assert!(st.virtual_wall > last_virtual);
        last_virtual = st.virtual_wall;
    }
    // The KL anchor: after a few steps the policy has moved off the
    // reference, so KL should be measurably positive.
    // (Not asserted strictly — with tied rewards gradients can vanish.)
    let acc = trainer.evaluate(1).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn hetero_fleet_faster_virtual_clock_than_small_homo() {
    let Some(rt) = runtime() else { return };
    let cfg = GrpoConfig {
        group_size: 4,
        max_new: 8,
        temperature: 1.0,
        difficulty: TaskDifficulty::Easy,
        seed: 5,
        expert_inject: true,
    };
    let mut homo =
        GrpoTrainer::new(&rt, cfg.clone(), WorkerFleet::homogeneous(3)).unwrap();
    let mut hetero =
        GrpoTrainer::new(&rt, cfg, WorkerFleet::heterogeneous_default()).unwrap();
    for _ in 0..2 {
        homo.step().unwrap();
        hetero.step().unwrap();
    }
    // Identical per-step work; the bigger mixed fleet advances virtual
    // wall-clock more slowly (i.e. trains faster in wall-clock terms).
    assert!(
        hetero.fleet.virtual_time < homo.fleet.virtual_time,
        "hetero {} vs homo {}",
        hetero.fleet.virtual_time,
        homo.fleet.virtual_time
    );
}

#[test]
fn same_seed_same_rollouts_across_fleets() {
    // Figures 8/9's premise: the fleet affects wall-clock, not learning.
    let Some(rt) = runtime() else { return };
    let cfg = GrpoConfig {
        group_size: 4,
        max_new: 8,
        temperature: 1.0,
        difficulty: TaskDifficulty::Hard,
        seed: 13,
        expert_inject: true,
    };
    let mut a = GrpoTrainer::new(&rt, cfg.clone(), WorkerFleet::homogeneous(2)).unwrap();
    let mut b =
        GrpoTrainer::new(&rt, cfg, WorkerFleet::heterogeneous_default()).unwrap();
    let sa = a.step().unwrap();
    let sb = b.step().unwrap();
    assert_eq!(sa.mean_reward, sb.mean_reward);
    assert_eq!(sa.loss, sb.loss);
    assert_eq!(a.policy.params[2], b.policy.params[2]);
}
