//! Delta evaluation vs the full re-price oracle.
//!
//! Two layers of the same contract:
//!
//! * **scheduler level** — a SHA+EA run with `delta_eval` on produces
//!   the bit-identical best plan / cost / eval count as the same run
//!   with it off, at every thread count in the test matrix, while
//!   performing strictly fewer per-task cost resolutions (delta prices
//!   only each candidate's dirty footprint). Delta evaluation changes
//!   *work*, never *results* — it consumes no randomness and alters no
//!   scores, so the candidate streams are identical;
//! * **cost-model level** — over a seeded chain of device-swap
//!   perturbations, [`CostModel::plan_cost_delta`] against the rolling
//!   baseline equals an uncached [`CostModel::plan_cost`] of the same
//!   mutant, `PlanCost` exactly (`==` on every f64 field), and each
//!   delta touches the cache exactly `dirty.len()` times.
//!
//! The chain plan assigns each task a disjoint 16-GPU slice, so a
//! device-pair swap dirties at most two of the four tasks — every step
//! asserts the delta priced strictly fewer tasks than a full re-price.

use hetrl::costmodel::{CostCache, CostModel, TaskCost};
use hetrl::plan::{ExecutionPlan, ParallelStrategy, TaskPlan};
use hetrl::scheduler::ea::perturbations_with_footprints;
use hetrl::scheduler::{Budget, ScheduleOutcome, Scheduler, ShaEaScheduler};
use hetrl::testing::fixtures;
use hetrl::topology::Scenario;

fn sha(seed: u64, threads: usize, delta: bool) -> ScheduleOutcome {
    let (wf, topo, job) = fixtures::env(Scenario::MultiCountry);
    let mut s = ShaEaScheduler::with_threads(seed, threads);
    s.cfg.ea.delta_eval = delta;
    s.schedule(&topo, &wf, &job, Budget::evals(300))
}

#[test]
fn delta_eval_bit_identical_to_full_and_strictly_cheaper() {
    for seed in [1u64, 5, 11] {
        for threads in fixtures::test_threads() {
            let full = sha(seed, threads, false);
            let delta = sha(seed, threads, true);
            assert!(full.cost.is_finite(), "seed {seed}: no plan");
            assert_eq!(
                delta.cost.to_bits(),
                full.cost.to_bits(),
                "seed {seed} threads {threads}: best cost diverged"
            );
            assert_eq!(delta.plan, full.plan, "seed {seed} threads {threads}: plan diverged");
            assert_eq!(delta.evals, full.evals, "seed {seed} threads {threads}: evals diverged");
            // Both modes look up exactly what they price, and the exact
            // accounting makes the counters assertable at any thread
            // count.
            for out in [&full, &delta] {
                assert_eq!(out.cache_hits + out.cache_misses, out.task_pricings);
            }
            // Every key delta mode skips was resolved when its
            // baseline was first priced, so the distinct-key (miss)
            // count matches full mode; only the lookup volume drops.
            assert_eq!(
                delta.cache_misses, full.cache_misses,
                "seed {seed} threads {threads}: distinct priced keys diverged"
            );
            assert!(
                delta.task_pricings < full.task_pricings,
                "seed {seed} threads {threads}: delta did not price fewer tasks ({} vs {})",
                delta.task_pricings,
                full.task_pricings
            );
        }
    }
}

/// All four GRPO tasks in one group over the whole fleet, each task on
/// its own disjoint 16-GPU slice (the 64-GPU single-region testbed).
fn disjoint_plan(wf: &hetrl::workflow::RlWorkflow, n_gpus: usize) -> ExecutionPlan {
    let mut task_plans = Vec::new();
    for (t, task) in wf.tasks.iter().enumerate() {
        let s = ParallelStrategy::new(2, 2, 4); // 16 GPUs per task
        let devs: Vec<usize> = (t * 16..(t + 1) * 16).collect();
        task_plans.push(TaskPlan::uniform(s, task.model.nl, devs));
    }
    ExecutionPlan {
        task_groups: vec![(0..wf.n_tasks()).collect()],
        gpu_groups: vec![(0..n_gpus).collect()],
        task_plans,
    }
}

#[test]
fn delta_pricing_matches_full_oracle_over_perturbation_chains() {
    let (wf, topo, job) = fixtures::env(Scenario::SingleRegion);
    let cm = CostModel::new(&topo, &wf, &job);
    let n_tasks = wf.n_tasks();
    for seed in [0u64, 3, 9] {
        let mut current = disjoint_plan(&wf, topo.n());
        current.validate(&wf, &topo, &job).expect("chain seed plan is valid");
        let cache = CostCache::new();
        let mut base: Vec<TaskCost> = cm.plan_cost(&current).per_task;
        for step in 0..8u64 {
            let (mutant, dirty) = perturbations_with_footprints(&current, 1, seed * 100 + step)
                .pop()
                .expect("one perturbation");
            assert!(
                dirty.len() < n_tasks,
                "seed {seed} step {step}: disjoint slices must keep the footprint partial"
            );
            let lookups0 = cache.hits() + cache.misses();
            let got = cm.plan_cost_delta(&mutant, &base, &dirty, &cache);
            let lookups1 = cache.hits() + cache.misses();
            assert_eq!(
                lookups1 - lookups0,
                dirty.len(),
                "seed {seed} step {step}: delta must touch the cache once per dirty task"
            );
            let oracle = cm.plan_cost(&mutant);
            assert_eq!(
                got, oracle,
                "seed {seed} step {step}: delta price diverged from the full oracle"
            );
            base = got.per_task;
            current = mutant;
        }
    }
}
