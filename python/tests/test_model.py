"""Layer-2 model invariants: shapes, causality, logprob semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (ModelCfg, forward_logits, forward_value,
                           init_params, param_names, param_shapes,
                           token_logprobs)

CFG = ModelCfg(vocab=32, d_model=64, n_heads=4, d_ff=128, n_layers=2,
               max_len=32)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


def toks(key, b=2, seq=None):
    seq = seq or CFG.max_len
    return jax.random.randint(key, (b, seq), 0, CFG.vocab)


class TestModel:
    def test_param_layout_consistent(self):
        names = param_names(CFG)
        shapes = param_shapes(CFG)
        assert len(names) == len(shapes)
        assert names[0] == "embed"
        assert names[-1] == "value_head"
        assert shapes[0] == (CFG.vocab, CFG.d_model)
        # 9 tensors per layer + embed + ln_f + unembed + value head
        assert len(names) == 9 * CFG.n_layers + 4

    def test_init_matches_shapes(self, params):
        for p, s in zip(params, param_shapes(CFG)):
            assert p.shape == s

    def test_logits_shape(self, params):
        t = toks(jax.random.PRNGKey(1))
        logits = forward_logits(CFG, params, t)
        assert logits.shape == (2, CFG.max_len, CFG.vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_causality(self, params):
        # Perturbing token t must not change logits before t.
        t = toks(jax.random.PRNGKey(2), b=1)
        l1 = forward_logits(CFG, params, t)
        t2 = t.at[0, -1].set((t[0, -1] + 1) % CFG.vocab)
        l2 = forward_logits(CFG, params, t2)
        np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], rtol=1e-5,
                                   atol=1e-5)

    def test_token_logprobs_are_logprobs(self, params):
        t = toks(jax.random.PRNGKey(3))
        lp = token_logprobs(CFG, params, t)
        assert lp.shape == (2, CFG.max_len - 1)
        assert bool((lp <= 1e-6).all())

    def test_token_logprobs_match_manual(self, params):
        t = toks(jax.random.PRNGKey(4), b=1)
        lp = token_logprobs(CFG, params, t)
        logits = forward_logits(CFG, params, t)
        full = jax.nn.log_softmax(logits, axis=-1)
        manual = full[0, jnp.arange(CFG.max_len - 1), t[0, 1:]]
        np.testing.assert_allclose(lp[0], manual, rtol=1e-6, atol=1e-6)

    def test_value_head_shape(self, params):
        t = toks(jax.random.PRNGKey(5))
        v = forward_value(CFG, params, t)
        assert v.shape == (2, CFG.max_len)
        assert bool(jnp.isfinite(v).all())

    def test_different_tokens_different_logits(self, params):
        a = forward_logits(CFG, params, toks(jax.random.PRNGKey(6)))
        b = forward_logits(CFG, params, toks(jax.random.PRNGKey(7)))
        assert float(jnp.abs(a - b).max()) > 1e-3


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
