"""Executable differential model of the Rust DES engine's shuffle
invariance (``rust/src/simulator/{des,component}.rs``).

This is a line-faithful port of the pieces that carry the
replay-order-invariance proof obligation: the crate's PRNG
(SplitMix64 -> xoshiro256**), the pinned reference executor
(``SimGraph::simulate_reference``), the component-engine executor with
its ``(ready_time, tie_rank, op_id)`` ready heap, the conflict-component
rank construction, and the ``testing::fixtures::random_sim_graph``
fixture. Python floats are IEEE-754 doubles and the simulator only
uses ``max``/``+``/``*``, so outcomes here are bit-comparable to the
Rust ones.

It exists because the invariance argument was once *wrong in a way a
desk-check missed*: ranking zero-duration ops (barriers, dur-0 queue
ops) as free-floating singleton components is unsound — their commit
releases successors *mid-instant*, so their pop position gates which
same-component op reaches a contended resource first. The fixed rank
construction couples every zero-duration op into its successors'
components. This suite

* reproduces the historical counterexample against the pre-fix rank
  scheme (a regression canary: the test FAILS if the unsound scheme
  ever looks invariant, i.e. the canary itself rots),
* runs the same DES-level fuzz as ``rust/tests/prop_interleave.rs``
  (identical graphs via the ported RNG + fixture, identical shuffle
  seeds) against the fixed scheme,
* and fuzzes far wider: dense-tie graphs, zero-duration-heavy graphs,
  adversarial *arbitrary* rank assignments (any per-component rank
  must be invariant, not just the seeded ones).

Runs with pytest or directly: ``python3 python/tests/test_des_shuffle.py``.
"""

import heapq
import itertools

MASK = (1 << 64) - 1
USIZE_MAX = (1 << 64) - 1  # tag for barriers; value irrelevant to the sim


# ----------------------------------------------------------------------
# util::rng (SplitMix64 seeding xoshiro256**), bit-exact
# ----------------------------------------------------------------------

def _splitmix64(state):
    state = (state + 0x9E37_79B9_7F4A_7C15) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK
    return state, (z ^ (z >> 31)) & MASK


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    def __init__(self, seed):
        sm = seed & MASK
        s = []
        for _ in range(4):
            sm, v = _splitmix64(sm)
            s.append(v)
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        assert n > 0
        zone = MASK - (MASK % n)
        while True:
            v = self.next_u64()
            if v < zone:
                return v % n

    def chance(self, p):
        return self.f64() < p


def tie_rank(seed, key):
    """ShuffleConfig::tie_rank: one draw off Rng(seed ^ key * GOLDEN)."""
    return Rng(seed ^ ((key * 0x9E37_79B9_7F4A_7C15) & MASK)).next_u64()


# ----------------------------------------------------------------------
# simulator::des::SimGraph + the two executors
# ----------------------------------------------------------------------

class Op:
    __slots__ = ("resources", "duration", "deps", "tag")

    def __init__(self, resources, duration, deps, tag):
        self.resources = resources
        self.duration = duration
        self.deps = deps
        self.tag = tag


class SimGraph:
    def __init__(self, n_resources):
        self.ops = []
        self.n_resources = n_resources

    def add_resource(self):
        self.n_resources += 1
        return self.n_resources - 1

    def add(self, resources, duration, deps, tag):
        op_id = len(self.ops)
        assert all(r < self.n_resources for r in resources)
        assert all(d < op_id for d in deps)
        assert duration >= 0.0
        self.ops.append(Op(resources, duration, deps, tag))
        return op_id

    def barrier(self, deps):
        return self.add([], 0.0, deps, USIZE_MAX)

    def ready_of(self, op_id, finish):
        r = 0.0
        for d in self.ops[op_id].deps:
            r = max(r, finish[d])
        return r


def _run(graph, rank):
    """One executor loop, ready heap keyed (ready, rank[id], id).

    With rank[id] == id this is ``simulate_reference`` /
    shuffle-off ``simulate()``; any other rank models a ShuffleConfig.
    The component Engine adds nothing observable while ResourceOwners
    are passive (the executor is the only component with finite ticks),
    so this loop *is* the engine semantics for both Rust code paths.
    """
    n = len(graph.ops)
    indeg = [len(op.deps) for op in graph.ops]
    rdeps = [[] for _ in range(n)]
    for op_id, op in enumerate(graph.ops):
        for d in op.deps:
            rdeps[d].append(op_id)
    free = [0.0] * graph.n_resources
    busy = [0.0] * graph.n_resources
    start = [None] * n
    finish = [None] * n
    heap = [(0.0, rank[i], i) for i in range(n) if indeg[i] == 0]
    heapq.heapify(heap)
    makespan = 0.0
    done = 0
    while heap:
        rt, _, op_id = heapq.heappop(heap)
        op = graph.ops[op_id]
        t0 = rt
        for r in op.resources:
            t0 = max(t0, free[r])
        t1 = t0 + op.duration
        for r in op.resources:
            free[r] = t1
            busy[r] += op.duration
        start[op_id] = t0
        finish[op_id] = t1
        makespan = max(makespan, t1)
        done += 1
        for succ in rdeps[op_id]:
            indeg[succ] -= 1
            if indeg[succ] == 0:
                heapq.heappush(heap, (graph.ready_of(succ, finish), rank[succ], succ))
    assert done == n, "cycle in sim graph"
    return makespan, start, finish, busy


def _find(parent, x):
    while parent[x] != x:
        parent[x] = parent[parent[x]]
        x = parent[x]
    return x


def _unite(parent, a, b):
    ra, rb = _find(parent, a), _find(parent, b)
    parent[max(ra, rb)] = min(ra, rb)


def rank_fifo(graph):
    return list(range(len(graph.ops)))


def rank_prefix_scheme(graph, seed):
    """The UNSOUND pre-fix rank construction (union-find over resources
    only; zero-resource ops are free-floating singletons). Kept as the
    counterexample target."""
    n = len(graph.ops)
    nr = graph.n_resources
    parent = list(range(nr))
    for op in graph.ops:
        for a, b in zip(op.resources, op.resources[1:]):
            _unite(parent, a, b)
    out = []
    for op_id, op in enumerate(graph.ops):
        key = _find(parent, op.resources[0]) if op.resources else nr + op_id
        out.append(tie_rank(seed, key))
    return out


def component_keys(graph):
    """The FIXED conflict components: union-find over resource nodes
    plus a virtual node per op; ops join their resources, and every
    zero-duration op is coupled into each successor's component.
    Mirrors OpExecutor::new in rust/src/simulator/component.rs."""
    n = len(graph.ops)
    nr = graph.n_resources
    rdeps = [[] for _ in range(n)]
    for op_id, op in enumerate(graph.ops):
        for d in op.deps:
            rdeps[d].append(op_id)
    parent = list(range(nr + n))
    for op_id, op in enumerate(graph.ops):
        for r in op.resources:
            _unite(parent, nr + op_id, r)
        if op.duration == 0.0:
            for succ in rdeps[op_id]:
                _unite(parent, nr + op_id, nr + succ)
    return [_find(parent, nr + op_id) for op_id in range(n)]


def rank_fixed_scheme(graph, seed):
    return [tie_rank(seed, key) for key in component_keys(graph)]


def simulate(graph):
    return _run(graph, rank_fifo(graph))


def simulate_with(graph, seed):
    return _run(graph, rank_fixed_scheme(graph, seed))


# ----------------------------------------------------------------------
# testing::fixtures::random_sim_graph, bit-exact port
# ----------------------------------------------------------------------

def random_sim_graph(seed, n_ops, n_resources):
    assert n_resources > 0
    rng = Rng(seed ^ 0x51D5_EED5_0DA6_0000)
    g = SimGraph(n_resources)
    links = [g.add_resource() for _ in range(min(n_resources, 2))]

    def pick_deps(upto, max_n):
        n = rng.below(max_n + 1)
        deps = [rng.below(upto) for _ in range(n)]
        return sorted(set(deps))

    for i in range(n_ops):
        if i > 0 and rng.chance(0.125):
            g.barrier(pick_deps(i, 3))
            continue
        resources = [rng.below(n_resources)]
        if rng.chance(0.25):
            r2 = rng.below(n_resources)
            if r2 != resources[0]:
                resources.append(r2)
        if rng.chance(0.2):
            resources.append(links[rng.below(len(links))])
        duration = rng.below(5) * 0.25
        deps = [] if i == 0 else pick_deps(i, 2)
        g.add(resources, duration, deps, i % 4)
    return g


def zero_heavy_graph(seed, n_ops, n_resources):
    """Adversarial fixture: ~half the ops are zero-duration (barriers
    and dur-0 resource ops), all durations quantized to {0, 1} so
    nearly every ready event is a same-instant tie."""
    rng = Rng(seed ^ 0x0DDB_A11_F00D)
    g = SimGraph(n_resources)
    for i in range(n_ops):
        deps = sorted({rng.below(i) for _ in range(rng.below(3))}) if i else []
        if rng.chance(0.25):
            g.barrier(deps)
            continue
        resources = sorted({rng.below(n_resources) for _ in range(1 + rng.below(2))})
        duration = 0.0 if rng.chance(0.35) else 1.0
        g.add(resources, duration, deps, 0)
    return g


# The exact constants from rust/tests/prop_interleave.rs.
SHUFFLE_SEEDS = [0, 2, 3, 5, 7, 11, 41, 0xDEAD_BEEF]


def _counterexample_graph():
    # REVIEW counterexample: A=barrier(id 0, dur 0), C=op(id 1, res 0,
    # dep A), B=op(id 2, res 0), all ready at t=0.
    g = SimGraph(1)
    a = g.barrier([])
    g.add([0], 1.0, [a], 0)
    g.add([0], 1.0, [], 0)
    return g


def test_rng_port_sanity():
    # xoshiro256** self-consistency of the port: deterministic per
    # seed, seed-sensitive, f64 in [0, 1).
    a, b = Rng(42), Rng(42)
    assert [a.next_u64() for _ in range(64)] == [b.next_u64() for _ in range(64)]
    assert Rng(1).next_u64() != Rng(2).next_u64()
    r = Rng(7)
    assert all(0.0 <= r.f64() < 1.0 for _ in range(10_000))
    assert tie_rank(7, 3) == tie_rank(7, 3)
    ranks7 = [tie_rank(7, i) for i in range(64)]
    assert ranks7 != [tie_rank(8, i) for i in range(64)]
    assert any(ranks7[i] < ranks7[i - 1] for i in range(1, 64))


def test_prefix_scheme_reproduces_the_review_counterexample():
    # Canary: the unsound scheme MUST diverge (if it ever stops
    # diverging, the model no longer reproduces the bug and every
    # other pass here proves nothing).
    g = _counterexample_graph()
    base = simulate(g)
    assert base[1] == [0.0, 0.0, 1.0]  # start = [A, C, B]
    diverged = [
        s for s in range(256)
        if _run(g, rank_prefix_scheme(g, s))[1] != base[1]
    ]
    assert diverged, "unsound rank scheme failed to reproduce the bug"
    # And the divergence is exactly the predicted one: B before C.
    s = diverged[0]
    assert _run(g, rank_prefix_scheme(g, s))[1] == [0.0, 1.0, 0.0]


def test_fixed_scheme_passes_the_counterexample():
    g = _counterexample_graph()
    base = simulate(g)
    for s in range(256):
        assert _run(g, rank_fixed_scheme(g, s)) == base, f"seed {s}"


def test_zero_duration_chain_couples_transitively():
    # q=op(res 1, dur 0) -> z=barrier -> c=op(res 0), racing
    # b=op(res 0): the dur-0 chain must ride into res 0's component.
    g = SimGraph(2)
    q = g.add([1], 0.0, [], 0)
    z = g.barrier([q])
    g.add([0], 1.0, [z], 0)
    g.add([0], 1.0, [], 0)
    base = simulate(g)
    assert base[1] == [0.0, 0.0, 0.0, 1.0]
    assert any(_run(g, rank_prefix_scheme(g, s)) != base for s in range(256))
    for s in range(256):
        assert _run(g, rank_fixed_scheme(g, s)) == base, f"seed {s}"
    # All four ops (and both resources) collapse into one component.
    assert len(set(component_keys(g))) == 1


def test_prop_interleave_des_fuzz_mirror():
    # The exact DES-level matrix from rust/tests/prop_interleave.rs:
    # graph seeds 0..6 x 150 ops x 4 devices, 8 shuffle seeds —
    # identical graphs (bit-exact RNG + fixture port), identical
    # seeds. This is the suite the review predicted would fail
    # pre-fix; the canary below confirms it did.
    prefix_diverged = 0
    for graph_seed in range(6):
        g = random_sim_graph(graph_seed, 150, 4)
        base = simulate(g)
        ref = _run(g, rank_fifo(g))
        assert ref == base  # shuffle-off == reference executor
        for s in SHUFFLE_SEEDS:
            assert simulate_with(g, s) == base, f"graph {graph_seed}, shuffle {s}"
            if _run(g, rank_prefix_scheme(g, s)) != base:
                prefix_diverged += 1
    assert prefix_diverged > 0, "canary: old scheme passed the prop_interleave fuzz"


def test_wide_fuzz_random_graphs():
    # Far beyond the Rust matrix: 3 sizes x 40 graph seeds x 8 shuffle
    # seeds on the shared fixture.
    for n_ops, n_res in [(30, 2), (80, 3), (150, 4)]:
        for graph_seed in range(40):
            g = random_sim_graph(1000 + graph_seed, n_ops, n_res)
            base = simulate(g)
            for s in SHUFFLE_SEEDS:
                assert simulate_with(g, s) == base, \
                    f"{n_ops} ops, graph {graph_seed}, shuffle {s}"


def test_wide_fuzz_zero_duration_heavy():
    # The adversarial regime the bug lived in: ~half zero durations,
    # every ready event a tie.
    for graph_seed in range(60):
        g = zero_heavy_graph(graph_seed, 60, 3)
        base = simulate(g)
        for s in SHUFFLE_SEEDS:
            assert simulate_with(g, s) == base, f"graph {graph_seed}, shuffle {s}"


def test_arbitrary_component_rank_assignments_are_invariant():
    # Stronger than seeded ranks: the proof claims invariance under
    # ANY rank that is constant per (fixed-scheme) component. Sweep
    # every permutation of component order on small graphs, plus
    # random assignments on bigger ones.
    for graph_seed in range(30):
        g = zero_heavy_graph(5000 + graph_seed, 9, 2)
        base = simulate(g)
        keys = component_keys(g)
        comps = sorted(set(keys))
        if len(comps) > 5:
            continue
        for perm in itertools.permutations(range(len(comps))):
            order = dict(zip(comps, perm))
            rank = [order[k] for k in keys]
            assert _run(g, rank) == base, f"graph {graph_seed}, perm {perm}"
    rng = Rng(99)
    for graph_seed in range(20):
        g = random_sim_graph(7000 + graph_seed, 100, 3)
        base = simulate(g)
        keys = component_keys(g)
        for _ in range(10):
            assign = {k: rng.next_u64() for k in set(keys)}
            rank = [assign[k] for k in keys]
            assert _run(g, rank) == base, f"graph {graph_seed}"


def main():
    tests = [(name, fn) for name, fn in sorted(globals().items())
             if name.startswith("test_") and callable(fn)]
    for name, fn in tests:
        fn()
        print(f"ok   {name}")
    print(f"{len(tests)} passed")


if __name__ == "__main__":
    main()
