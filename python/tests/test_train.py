"""Training-step semantics: the GRPO loss descends on a toy task, Adam
updates all tensors, and the critic MSE shrinks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ModelCfg, init_params, token_logprobs
from compile.train import (adam_update, grpo_loss, grpo_train_step,
                           ppo_critic_loss, ppo_critic_train_step)

CFG = ModelCfg(vocab=16, d_model=32, n_heads=2, d_ff=64, n_layers=2,
               max_len=16)


def batch(key, params, b=4):
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (b, CFG.max_len), 0, CFG.vocab)
    logp = token_logprobs(CFG, params, tokens)
    adv = jnp.where(jnp.arange(b) % 2 == 0, 1.0, -1.0)
    mask = jnp.ones((b, CFG.max_len - 1), jnp.float32)
    # Behaviour = reference = current policy at step 0.
    return tokens, logp, logp, adv, mask


class TestGrpoStep:
    def test_loss_finite_and_kl_zero_at_start(self):
        params = init_params(CFG, jax.random.PRNGKey(0))
        tokens, lpo, lpr, adv, mask = batch(jax.random.PRNGKey(1), params)
        loss, kl = grpo_loss(CFG, params, tokens, lpo, lpr, adv, mask)
        assert bool(jnp.isfinite(loss))
        assert abs(float(kl)) < 1e-5  # identical policies

    def test_step_increases_positive_adv_logprobs(self):
        params = init_params(CFG, jax.random.PRNGKey(0))
        tokens, lpo, lpr, adv, mask = batch(jax.random.PRNGKey(1), params)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        new_p = params
        for step in range(5):
            new_p, m, v, loss, kl = grpo_train_step(
                CFG, new_p, m, v, jnp.float32(step + 1), tokens, lpo, lpr,
                adv, mask, lr=1e-2)
        lp_after = token_logprobs(CFG, new_p, tokens)
        lp_before = lpo
        gain = ((lp_after - lp_before) * mask).sum(axis=-1)
        pos = gain[adv > 0].mean()
        neg = gain[adv < 0].mean()
        assert float(pos) > float(neg), (pos, neg)

    def test_adam_updates_every_tensor(self):
        params = init_params(CFG, jax.random.PRNGKey(2))
        grads = [jnp.ones_like(p) for p in params]
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        new_p, new_m, new_v = adam_update(params, grads, m, v,
                                          jnp.float32(1.0), lr=1e-3)
        for p, np_, nm in zip(params, new_p, new_m):
            assert float(jnp.abs(p - np_).max()) > 0
            assert float(jnp.abs(nm).max()) > 0

    def test_adam_step_size_bounded_by_lr(self):
        params = init_params(CFG, jax.random.PRNGKey(3))
        grads = [jnp.full_like(p, 7.0) for p in params]
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        new_p, _, _ = adam_update(params, grads, m, v, jnp.float32(1.0),
                                  lr=1e-3)
        for p, np_ in zip(params, new_p):
            # Bias-corrected first step ≈ lr * sign(g).
            assert float(jnp.abs(p - np_).max()) < 2e-3


class TestCriticStep:
    def test_mse_descends(self):
        params = init_params(CFG, jax.random.PRNGKey(4))
        key = jax.random.PRNGKey(5)
        tokens = jax.random.randint(key, (4, CFG.max_len), 0, CFG.vocab)
        returns = jnp.ones((4, CFG.max_len - 1), jnp.float32) * 0.5
        mask = jnp.ones_like(returns)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        l0 = float(ppo_critic_loss(CFG, params, tokens, returns, mask))
        p = params
        for step in range(10):
            p, m, v, loss = ppo_critic_train_step(
                CFG, p, m, v, jnp.float32(step + 1), tokens, returns, mask,
                lr=5e-3)
        l1 = float(ppo_critic_loss(CFG, p, tokens, returns, mask))
        assert l1 < l0 * 0.9, (l0, l1)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
