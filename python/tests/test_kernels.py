"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles, including
hypothesis sweeps over shapes and gradient checks through the custom
VJPs (the CORE correctness signal for the AOT path)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.flash_attention import flash_attention, vmem_report
from compile.kernels.fused_loss import grpo_token_loss

jax.config.update("jax_enable_x64", False)


def rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

class TestFlashAttention:
    def test_matches_ref_basic(self):
        k = jax.random.split(jax.random.PRNGKey(0), 3)
        q, kk, v = (rand(ki, 2, 4, 64, 32) for ki in k)
        out = flash_attention(q, kk, v)
        want = ref.attention_ref(q, kk, v)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)

    def test_single_block(self):
        k = jax.random.split(jax.random.PRNGKey(1), 3)
        q, kk, v = (rand(ki, 1, 1, 16, 8) for ki in k)
        out = flash_attention(q, kk, v, block_q=16, block_k=16)
        want = ref.attention_ref(q, kk, v)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)

    def test_blocking_invariance(self):
        k = jax.random.split(jax.random.PRNGKey(2), 3)
        q, kk, v = (rand(ki, 1, 2, 64, 16) for ki in k)
        a = flash_attention(q, kk, v, block_q=64, block_k=64)
        b = flash_attention(q, kk, v, block_q=16, block_k=32)
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)

    def test_causality(self):
        # Changing a future token must not change past outputs.
        k = jax.random.split(jax.random.PRNGKey(3), 3)
        q, kk, v = (rand(ki, 1, 2, 32, 16) for ki in k)
        out1 = flash_attention(q, kk, v)
        kk2 = kk.at[:, :, -1].add(100.0)
        v2 = v.at[:, :, -1].add(100.0)
        out2 = flash_attention(q, kk2, v2)
        np.testing.assert_allclose(out1[:, :, :-1], out2[:, :, :-1],
                                   rtol=2e-5, atol=2e-5)

    def test_gradients_match_ref(self):
        keys = jax.random.split(jax.random.PRNGKey(4), 3)
        q, kk, v = (rand(ki, 1, 2, 32, 16) for ki in keys)

        def loss_kernel(q, k, v):
            return (flash_attention(q, k, v) ** 2).sum()

        def loss_ref(q, k, v):
            return (ref.attention_ref(q, k, v) ** 2).sum()

        gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, kk, v)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, kk, v)
        for a, b, name in zip(gk, gr, "qkv"):
            np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5,
                                       err_msg=f"d{name}")

    @hypothesis.given(
        b=st.integers(1, 3),
        h=st.integers(1, 4),
        seq_pow=st.integers(3, 6),
        d_pow=st.integers(2, 5),
        seed=st.integers(0, 2**31 - 1),
    )
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_shape_sweep(self, b, h, seq_pow, d_pow, seed):
        seq, d = 2 ** seq_pow, 2 ** d_pow
        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        q, kk, v = (rand(ki, b, h, seq, d) for ki in keys)
        out = flash_attention(q, kk, v)
        want = ref.attention_ref(q, kk, v)
        assert out.shape == (b, h, seq, d)
        np.testing.assert_allclose(out, want, rtol=5e-5, atol=5e-5)

    def test_vmem_report_structure(self):
        r = vmem_report(seq=1024, d=128, block_q=128, block_k=128)
        assert r["vmem_bytes"] < 8 * 1024 * 1024  # fits VMEM budget
        assert r["mxu_tile_utilization"] == 1.0


# ----------------------------------------------------------------------
# fused GRPO loss
# ----------------------------------------------------------------------

def _loss_inputs(seed, b=4, seq=16):
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    lpn = -jnp.abs(rand(keys[0], b, seq))
    lpo = lpn + 0.3 * rand(keys[1], b, seq)
    lpr = lpn + 0.3 * rand(keys[2], b, seq)
    adv = jnp.broadcast_to(rand(keys[3], b)[:, None], (b, seq))
    mask = (jax.random.uniform(keys[4], (b, seq)) > 0.3).astype(jnp.float32)
    return lpn, lpo, lpr, adv, mask


class TestFusedLoss:
    def test_matches_ref(self):
        lpn, lpo, lpr, adv, mask = _loss_inputs(0)
        got = grpo_token_loss(lpn, lpo, lpr, adv, mask)
        want = ref.grpo_token_loss_ref(lpn, lpo, lpr, adv, mask)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_gradient_matches_analytic(self):
        lpn, lpo, lpr, adv, mask = _loss_inputs(1)
        g = jax.grad(lambda x: grpo_token_loss(x, lpo, lpr, adv, mask).sum())(lpn)
        want = ref.grpo_token_grad_ref(lpn, lpo, lpr, adv, mask)
        np.testing.assert_allclose(g, want, rtol=1e-5, atol=1e-6)

    def test_gradient_matches_autodiff_of_ref(self):
        lpn, lpo, lpr, adv, mask = _loss_inputs(2)
        g_kernel = jax.grad(
            lambda x: grpo_token_loss(x, lpo, lpr, adv, mask).sum())(lpn)
        g_auto = jax.grad(
            lambda x: ref.grpo_token_loss_ref(x, lpo, lpr, adv, mask).sum())(lpn)
        np.testing.assert_allclose(g_kernel, g_auto, rtol=1e-4, atol=1e-5)

    def test_mask_zeroes_loss(self):
        lpn, lpo, lpr, adv, _ = _loss_inputs(3)
        zero_mask = jnp.zeros_like(lpn)
        got = grpo_token_loss(lpn, lpo, lpr, adv, zero_mask)
        assert float(jnp.abs(got).max()) == 0.0

    def test_identical_policies_loss_is_minus_adv_like(self):
        # ratio == 1, kl == 0 → loss = -adv per token.
        lpn, _, _, adv, mask = _loss_inputs(4)
        got = grpo_token_loss(lpn, lpn, lpn, adv, mask)
        np.testing.assert_allclose(got, -adv * mask, rtol=1e-5, atol=1e-6)

    @hypothesis.given(
        b=st.integers(1, 6),
        seq=st.integers(2, 64),
        clip=st.floats(0.05, 0.5),
        beta=st.floats(0.0, 0.2),
        seed=st.integers(0, 2**31 - 1),
    )
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_sweep(self, b, seq, clip, beta, seed):
        lpn, lpo, lpr, adv, mask = _loss_inputs(seed, b, seq)
        got = grpo_token_loss(lpn, lpo, lpr, adv, mask, clip, beta)
        want = ref.grpo_token_loss_ref(lpn, lpo, lpr, adv, mask, clip, beta)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
