"""AOT sanity: entry points lower to parseable HLO text, the manifest is
consistent, and the lowered logprobs agree with the eager path."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import ModelCfg, init_params, token_logprobs


TINY = ModelCfg(vocab=16, d_model=32, n_heads=2, d_ff=64, n_layers=2,
                max_len=16)


@pytest.fixture(scope="module")
def built():
    d = tempfile.mkdtemp(prefix="hetrl_aot_")
    manifest = aot.build(TINY, batch=2, out_dir=d, lr=1e-3, clip_eps=0.2,
                         kl_beta=0.04)
    return d, manifest


class TestAot:
    def test_manifest_lists_all_entrypoints(self, built):
        d, manifest = built
        for name in ["init", "forward", "logprobs", "reward", "value",
                     "grpo_train", "critic_train"]:
            assert name in manifest["entrypoints"]
            path = os.path.join(d, manifest["entrypoints"][name]["file"])
            assert os.path.getsize(path) > 1000

    def test_hlo_is_text(self, built):
        d, manifest = built
        path = os.path.join(d, manifest["entrypoints"]["forward"]["file"])
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head

    def test_manifest_shapes_match_model(self, built):
        _, manifest = built
        assert manifest["n_params"] == len(manifest["param_shapes"])
        fwd = manifest["entrypoints"]["forward"]
        assert fwd["inputs"][-1]["shape"] == [2, TINY.max_len]
        assert fwd["inputs"][-1]["dtype"] == "i32"
        assert fwd["outputs"][0]["shape"] == [2, TINY.max_len, TINY.vocab]
        gt = manifest["entrypoints"]["grpo_train"]
        n = manifest["n_params"]
        assert len(gt["inputs"]) == 3 * n + 6
        assert len(gt["outputs"]) == 3 * n + 2

    def test_manifest_roundtrips_json(self, built):
        d, _ = built
        with open(os.path.join(d, "manifest.json")) as f:
            m = json.load(f)
        assert m["model"]["d_model"] == TINY.d_model

    def test_lowered_logprobs_match_eager(self, built):
        # Compile the lowered stablehlo with jax itself and compare: this
        # is the same computation the rust PJRT client executes.
        params = init_params(TINY, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1),
                                    (2, TINY.max_len), 0, TINY.vocab)

        def fn(*a):
            return (token_logprobs(TINY, list(a[:-1]), a[-1]),)

        lowered = jax.jit(fn).lower(*params, tokens)
        compiled = lowered.compile()
        got = compiled(*params, tokens)[0]
        want = token_logprobs(TINY, params, tokens)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
