"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: pytest (and hypothesis sweeps)
assert that the Pallas kernels in `flash_attention.py` and
`fused_loss.py` match these to tight tolerances, including gradients.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, sm_scale=None):
    """Causal multi-head attention, materializing the full score matrix.

    Args:
        q, k, v: ``[B, H, L, D]`` float arrays.
        sm_scale: optional softmax scale; defaults to ``1/sqrt(D)``.

    Returns:
        ``[B, H, L, D]`` attention output.
    """
    d = q.shape[-1]
    if sm_scale is None:
        sm_scale = 1.0 / jnp.sqrt(jnp.float32(d))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * sm_scale
    l_q, l_k = q.shape[2], k.shape[2]
    mask = jnp.tril(jnp.ones((l_q, l_k), dtype=bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def grpo_token_loss_ref(logp_new, logp_old, logp_ref, adv, mask,
                        clip_eps=0.2, kl_beta=0.04):
    """Token-level GRPO objective: clipped policy-gradient + k3 KL penalty.

    Args:
        logp_new: ``[B, L]`` log-probs of the taken tokens under the
            current policy.
        logp_old: ``[B, L]`` log-probs under the behaviour (rollout)
            policy.
        logp_ref: ``[B, L]`` log-probs under the frozen reference policy.
        adv:      ``[B, L]`` advantages (GRPO: group-normalized reward,
            broadcast over tokens).
        mask:     ``[B, L]`` 1.0 on response tokens, 0.0 elsewhere.

    Returns:
        ``[B, L]`` per-token loss (positive = to minimize).
    """
    ratio = jnp.exp(logp_new - logp_old)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    pg = -jnp.minimum(unclipped, clipped)
    # k3 KL estimator: exp(ref-new) - (ref-new) - 1 >= 0
    delta = logp_ref - logp_new
    kl = jnp.exp(delta) - delta - 1.0
    return (pg + kl_beta * kl) * mask


def grpo_token_grad_ref(logp_new, logp_old, logp_ref, adv, mask,
                        clip_eps=0.2, kl_beta=0.04):
    """Analytic d(loss_token)/d(logp_new) — used to test the fused
    kernel's custom VJP."""
    ratio = jnp.exp(logp_new - logp_old)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    # d(-min(u, c))/dlogp_new
    use_unclipped = unclipped <= clipped
    inside = (ratio >= 1.0 - clip_eps) & (ratio <= 1.0 + clip_eps)
    dpg = -adv * ratio * jnp.where(use_unclipped, 1.0, inside.astype(ratio.dtype))
    delta = logp_ref - logp_new
    dkl = -jnp.exp(delta) + 1.0
    return (dpg + kl_beta * dkl) * mask
