"""Fused GRPO/PPO token-loss Pallas kernel.

Computes the clipped policy-gradient + k3-KL token loss *and* its
analytic gradient w.r.t. the new log-probs in one pass (the gradient is
the kernel's second output, wired into a custom VJP), so the training
step never materializes the intermediate ratio/clip tensors in HBM.

Matches `ref.grpo_token_loss_ref` / `ref.grpo_token_grad_ref` exactly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _loss_kernel(lpn_ref, lpo_ref, lpr_ref, adv_ref, mask_ref,
                 loss_ref, grad_ref, *, clip_eps, kl_beta):
    lpn = lpn_ref[...]
    lpo = lpo_ref[...]
    lpr = lpr_ref[...]
    adv = adv_ref[...]
    mask = mask_ref[...]

    ratio = jnp.exp(lpn - lpo)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    pg = -jnp.minimum(unclipped, clipped)
    delta = lpr - lpn
    kl = jnp.exp(delta) - delta - 1.0
    loss_ref[...] = (pg + kl_beta * kl) * mask

    use_unclipped = unclipped <= clipped
    inside = (ratio >= 1.0 - clip_eps) & (ratio <= 1.0 + clip_eps)
    dpg = -adv * ratio * jnp.where(use_unclipped, 1.0,
                                   inside.astype(ratio.dtype))
    dkl = -jnp.exp(delta) + 1.0
    grad_ref[...] = (dpg + kl_beta * dkl) * mask


def _run_kernel(lpn, lpo, lpr, adv, mask, clip_eps, kl_beta):
    b, seq = lpn.shape
    kernel = functools.partial(_loss_kernel, clip_eps=clip_eps,
                               kl_beta=kl_beta)
    # Row blocks: one batch row per grid step keeps the block well under
    # VMEM for any realistic sequence length.
    spec = pl.BlockSpec((1, seq), lambda i: (i, 0))
    loss, grad = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[spec] * 5,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, seq), lpn.dtype),
            jax.ShapeDtypeStruct((b, seq), lpn.dtype),
        ],
        interpret=True,
    )(lpn, lpo, lpr, adv, mask)
    return loss, grad


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def grpo_token_loss(logp_new, logp_old, logp_ref, adv, mask,
                    clip_eps=0.2, kl_beta=0.04):
    """Per-token GRPO loss ``[B, L]``; differentiable in `logp_new`
    (the other inputs are treated as constants, as in PPO/GRPO)."""
    loss, _ = _run_kernel(logp_new, logp_old, logp_ref, adv, mask,
                          clip_eps, kl_beta)
    return loss


def _loss_fwd(logp_new, logp_old, logp_ref, adv, mask, clip_eps, kl_beta):
    loss, grad = _run_kernel(logp_new, logp_old, logp_ref, adv, mask,
                             clip_eps, kl_beta)
    return loss, grad


def _loss_bwd(clip_eps, kl_beta, grad, g):
    # d(loss)/d(logp_new) = grad ⊙ cotangent; other inputs get zeros.
    dlpn = grad * g
    zeros = jnp.zeros_like(grad)
    return dlpn, zeros, zeros, zeros, zeros


grpo_token_loss.defvjp(_loss_fwd, _loss_bwd)
