"""Flash-attention in Pallas: tiled causal attention with online softmax,
forward + custom-VJP backward kernels.

Hardware adaptation (paper → TPU, DESIGN.md §3): the paper's fleet is
CUDA GPUs where flash attention tiles into SM shared memory; here the
HBM→VMEM staging is expressed with `BlockSpec` blocks and the reduction
axis is the minor grid dimension so output blocks accumulate in place.
Block sizes default to MXU-friendly multiples (the last dim stays the
head dim; Q/K tiles are 128-row tiles on real TPUs, shrunk automatically
for the small models used on the CPU-interpret substrate).

All `pallas_call`s use ``interpret=True``: the CPU PJRT client cannot
execute Mosaic custom-calls, and interpret mode lowers the kernel to
plain HLO that the rust runtime loads (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _pick_block(n, preferred=128):
    """Largest divisor of n that is ≤ preferred (≥ 1)."""
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return max(b, 1)


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                sm_scale, block_q, block_k, n_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                      # [BQ, D]
    k = k_ref[0]                      # [BK, D]
    v = v_ref[0]                      # [BK, D]
    s = jnp.dot(q, k.T) * sm_scale    # [BQ, BK]

    q_idx = qi * block_q + jnp.arange(block_q)
    k_idx = ki * block_k + jnp.arange(block_k)
    causal = q_idx[:, None] >= k_idx[None, :]
    s = jnp.where(causal, s, NEG_INF)

    m_prev = m_ref[0]                 # [BQ]
    l_prev = l_ref[0]
    o_prev = o_ref[0]

    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])   # [BQ, BK]
    l_new = alpha * l_prev + p.sum(axis=-1)
    o_new = o_prev * alpha[:, None] + jnp.dot(p, v)

    m_ref[0] = m_new
    l_ref[0] = l_new

    @pl.when(ki == n_kv - 1)
    def _final():
        o_ref[0] = o_new / l_new[:, None]

    @pl.when(ki != n_kv - 1)
    def _carry():
        o_ref[0] = o_new


def _fwd(q, k, v, sm_scale, block_q, block_k):
    bh, seq, d = q.shape
    n_q = seq // block_q
    n_kv = seq // block_k
    grid = (bh, n_q, n_kv)
    kernel = functools.partial(
        _fwd_kernel, sm_scale=sm_scale, block_q=block_q, block_k=block_k,
        n_kv=n_kv)
    out_shapes = [
        jax.ShapeDtypeStruct((bh, seq, d), q.dtype),   # o
        jax.ShapeDtypeStruct((bh, seq), q.dtype),      # m (running max)
        jax.ShapeDtypeStruct((bh, seq), q.dtype),      # l (running denom)
    ]
    o, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_q), lambda b, qi, ki: (b, qi)),
            pl.BlockSpec((1, block_q), lambda b, qi, ki: (b, qi)),
        ],
        out_shape=out_shapes,
        interpret=True,
    )(q, k, v)
    lse = m + jnp.log(l)
    return o, lse


# ----------------------------------------------------------------------
# backward
# ----------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
               sm_scale, block_q, block_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0]
    lse = lse_ref[0]                  # [BQ]
    delta = delta_ref[0]              # [BQ]

    s = jnp.dot(q, k.T) * sm_scale
    q_idx = qi * block_q + jnp.arange(block_q)
    k_idx = ki * block_k + jnp.arange(block_k)
    causal = q_idx[:, None] >= k_idx[None, :]
    s = jnp.where(causal, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])     # true softmax probs
    dp = jnp.dot(do, v.T)             # [BQ, BK]
    ds = p * (dp - delta[:, None]) * sm_scale
    dq_ref[0] += jnp.dot(ds, k)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, *, sm_scale, block_q, block_k):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0]
    lse = lse_ref[0]
    delta = delta_ref[0]

    s = jnp.dot(q, k.T) * sm_scale    # [BQ, BK]
    q_idx = qi * block_q + jnp.arange(block_q)
    k_idx = ki * block_k + jnp.arange(block_k)
    causal = q_idx[:, None] >= k_idx[None, :]
    s = jnp.where(causal, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    dv_ref[0] += jnp.dot(p.T, do)
    dp = jnp.dot(do, v.T)
    ds = p * (dp - delta[:, None]) * sm_scale
    dk_ref[0] += jnp.dot(ds.T, q)


def _bwd_impl(q, k, v, o, lse, do, sm_scale, block_q, block_k):
    bh, seq, d = q.shape
    n_q = seq // block_q
    n_kv = seq // block_k
    delta = jnp.sum(do * o, axis=-1)  # [BH, L]

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, sm_scale=sm_scale,
                          block_q=block_q, block_k=block_k),
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_q), lambda b, qi, ki: (b, qi)),
            pl.BlockSpec((1, block_q), lambda b, qi, ki: (b, qi)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
        interpret=True,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, sm_scale=sm_scale,
                          block_q=block_q, block_k=block_k),
        grid=(bh, n_kv, n_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, ki, qi: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ki, qi: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ki, qi: (b, ki, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, ki, qi: (b, qi, 0)),
            pl.BlockSpec((1, block_q), lambda b, ki, qi: (b, qi)),
            pl.BlockSpec((1, block_q), lambda b, ki, qi: (b, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda b, ki, qi: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ki, qi: (b, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
        ],
        interpret=True,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# ----------------------------------------------------------------------
# public API with custom VJP
# ----------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_bhld(q, k, v, sm_scale, block_q, block_k):
    o, _ = _fwd(q, k, v, sm_scale, block_q, block_k)
    return o


def _flash_fwd_rule(q, k, v, sm_scale, block_q, block_k):
    o, lse = _fwd(q, k, v, sm_scale, block_q, block_k)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(sm_scale, block_q, block_k, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _bwd_impl(q, k, v, o, lse, do, sm_scale, block_q, block_k)
    return dq, dk, dv


_flash_bhld.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(q, k, v, sm_scale=None, block_q=None, block_k=None):
    """Causal flash attention.

    Args:
        q, k, v: ``[B, H, L, D]``.
        sm_scale: softmax scale (default ``1/sqrt(D)``).
        block_q/block_k: tile sizes; default the largest divisor of L
            that is ≤ 128 (MXU tile) — shrinks automatically for the
            small interpret-mode models.

    Returns:
        ``[B, H, L, D]`` output; differentiable via the Pallas backward
        kernels.
    """
    b, h, seq, d = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    if block_q is None:
        block_q = _pick_block(seq)
    if block_k is None:
        block_k = _pick_block(seq)
    qf = q.reshape(b * h, seq, d)
    kf = k.reshape(b * h, seq, d)
    vf = v.reshape(b * h, seq, d)
    o = _flash_bhld(qf, kf, vf, float(sm_scale), int(block_q), int(block_k))
    return o.reshape(b, h, seq, d)


def vmem_report(seq, d, block_q, block_k, dtype_bytes=2):
    """Estimated VMEM working set of the forward kernel (bytes) and MXU
    tile utilization — the structural L1 'profile' recorded in
    EXPERIMENTS.md §Perf (interpret-mode wallclock is meaningless)."""
    tiles = (block_q * d + 2 * block_k * d    # q + k + v blocks
             + block_q * block_k              # scores
             + block_q * d + 2 * block_q)     # o + m + l
    mxu_util = min(block_q, 128) * min(block_k, 128) / (128.0 * 128.0)
    return {
        "vmem_bytes": tiles * dtype_bytes,
        "mxu_tile_utilization": mxu_util,
        "hbm_reads_per_block": (block_q + 2 * block_k) * d * dtype_bytes,
        "seq": seq,
    }
