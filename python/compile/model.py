"""Layer 2: the JAX transformer used by the real execution engine.

Decoder-only LM (RMSNorm, SwiGLU MLP, causal flash attention from the
Layer-1 Pallas kernel), with a value head for PPO critics. Parameters
are a flat, deterministically-ordered list of arrays so the rust runtime
can thread them through PJRT executables without a pytree library.
"""

import dataclasses
import math
from typing import List

import jax
import jax.numpy as jnp

from .kernels.flash_attention import flash_attention


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    vocab: int = 64
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    n_layers: int = 4
    max_len: int = 96

    @property
    def head_dim(self):
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Parameter layout: names in flattened order (the manifest contract).
def param_names(cfg: ModelCfg) -> List[str]:
    names = ["embed"]
    for i in range(cfg.n_layers):
        names += [
            f"l{i}.ln1", f"l{i}.wq", f"l{i}.wk", f"l{i}.wv", f"l{i}.wo",
            f"l{i}.ln2", f"l{i}.w_gate", f"l{i}.w_up", f"l{i}.w_down",
        ]
    names += ["ln_f", "unembed", "value_head"]
    return names


def param_shapes(cfg: ModelCfg) -> List[tuple]:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    shapes = [(v, d)]
    for _ in range(cfg.n_layers):
        shapes += [(d,), (d, d), (d, d), (d, d), (d, d),
                   (d,), (d, f), (d, f), (f, d)]
    shapes += [(d,), (d, v), (d, 1)]
    return shapes


def init_params(cfg: ModelCfg, key) -> List[jnp.ndarray]:
    shapes = param_shapes(cfg)
    params = []
    keys = jax.random.split(key, len(shapes))
    for k, shape in zip(keys, shapes):
        if len(shape) == 1:
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0]
            params.append(
                jax.random.normal(k, shape, jnp.float32) / math.sqrt(fan_in))
    return params


def _rms_norm(x, g):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * g


def _unpack(cfg: ModelCfg, params):
    it = iter(params)
    embed = next(it)
    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "ln1": next(it), "wq": next(it), "wk": next(it),
            "wv": next(it), "wo": next(it), "ln2": next(it),
            "w_gate": next(it), "w_up": next(it), "w_down": next(it),
        })
    ln_f = next(it)
    unembed = next(it)
    value_head = next(it)
    return embed, layers, ln_f, unembed, value_head


def trunk(cfg: ModelCfg, params, tokens):
    """Shared transformer trunk: tokens ``[B, L]`` → hidden ``[B, L, D]``."""
    embed, layers, ln_f, _, _ = _unpack(cfg, params)
    x = embed[tokens]  # [B, L, D]
    b, seq, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    for lyr in layers:
        y = _rms_norm(x, lyr["ln1"])
        q = (y @ lyr["wq"]).reshape(b, seq, h, hd).transpose(0, 2, 1, 3)
        k = (y @ lyr["wk"]).reshape(b, seq, h, hd).transpose(0, 2, 1, 3)
        v = (y @ lyr["wv"]).reshape(b, seq, h, hd).transpose(0, 2, 1, 3)
        att = flash_attention(q, k, v)
        att = att.transpose(0, 2, 1, 3).reshape(b, seq, d)
        x = x + att @ lyr["wo"]
        y = _rms_norm(x, lyr["ln2"])
        gate = jax.nn.silu(y @ lyr["w_gate"])
        up = y @ lyr["w_up"]
        x = x + (gate * up) @ lyr["w_down"]
    return _rms_norm(x, ln_f)


def forward_logits(cfg: ModelCfg, params, tokens):
    """tokens ``[B, L]`` → logits ``[B, L, V]``."""
    _, _, _, unembed, _ = _unpack(cfg, params)
    return trunk(cfg, params, tokens) @ unembed


def forward_value(cfg: ModelCfg, params, tokens):
    """tokens ``[B, L]`` → per-token value ``[B, L]`` (PPO critic)."""
    _, _, _, _, value_head = _unpack(cfg, params)
    return (trunk(cfg, params, tokens) @ value_head)[..., 0]


def token_logprobs(cfg: ModelCfg, params, tokens):
    """Log-prob of each *next* token: ``[B, L-1]`` where entry ``t`` is
    ``log p(tokens[t+1] | tokens[:t+1])``."""
    logits = forward_logits(cfg, params, tokens)          # [B, L, V]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)    # [B, L-1, V]
    nxt = tokens[:, 1:]
    return jnp.take_along_axis(logp, nxt[..., None], axis=-1)[..., 0]
