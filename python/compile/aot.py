"""AOT compiler: lowers the Layer-2 entry points to HLO **text** plus a
JSON manifest the rust runtime consumes.

HLO text — not serialized protos — is the interchange format: jax ≥ 0.5
emits 64-bit instruction ids that the crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Entry points (all shapes fixed at lowering time):
    init        (seed[2] u32)                      → params…
    forward     (params…, tokens[B,L])             → logits[B,L,V]
    logprobs    (params…, tokens[B,L])             → logp[B,L-1]
    reward      (params…, tokens[B,L])             → score[B]
    value       (params…, tokens[B,L])             → values[B,L]
    grpo_train  (params…, m…, v…, step, tokens,
                 logp_old, logp_ref, adv, mask)    → params…, m…, v…, loss, kl
    critic_train(params…, m…, v…, step, tokens,
                 returns, mask)                    → params…, m…, v…, loss

Run once via `make artifacts`; python never runs on the request path.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelCfg, init_params, param_names, param_shapes
from . import model as M
from . import train as T

F32 = jnp.float32
I32 = jnp.int32
U32 = jnp.uint32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def dtype_name(d):
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[jnp.dtype(d).name]


def lower_entry(fn, example_args):
    # keep_unused: entry points take the FULL parameter list even when a
    # head is unused (forward ignores value_head etc.) so the rust side
    # can thread one state tuple through every executable.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = to_hlo_text(lowered)
    inputs = []

    def collect(x):
        inputs.append({"shape": list(x.shape), "dtype": dtype_name(x.dtype)})

    jax.tree_util.tree_map(collect, example_args)
    out = jax.eval_shape(fn, *example_args)
    outputs = []
    jax.tree_util.tree_map(
        lambda x: outputs.append(
            {"shape": list(x.shape), "dtype": dtype_name(x.dtype)}),
        out,
    )
    return text, inputs, outputs


def build(cfg: ModelCfg, batch: int, out_dir: str, lr: float,
          clip_eps: float, kl_beta: float) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    shapes = param_shapes(cfg)
    p_specs = [spec(s) for s in shapes]
    tok = spec((batch, cfg.max_len), I32)
    seq1 = spec((batch, cfg.max_len - 1))
    advs = spec((batch,))
    step_s = spec(())

    entries = {}

    def emit(name, fn, args):
        text, inputs, outputs = lower_entry(fn, args)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries[name] = {"file": fname, "inputs": inputs, "outputs": outputs}
        print(f"  {name}: {len(text)} chars, {len(inputs)} in, "
              f"{len(outputs)} out")

    print(f"lowering entry points (d={cfg.d_model}, layers={cfg.n_layers}, "
          f"vocab={cfg.vocab}, maxlen={cfg.max_len}, batch={batch})")

    emit("init",
         lambda seed: tuple(init_params(
             cfg, jax.random.wrap_key_data(seed, impl="threefry2x32"))),
         (spec((2,), U32),))

    emit("forward",
         lambda *a: (M.forward_logits(cfg, list(a[:-1]), a[-1]),),
         (*p_specs, tok))

    emit("logprobs",
         lambda *a: (M.token_logprobs(cfg, list(a[:-1]), a[-1]),),
         (*p_specs, tok))

    emit("reward",
         lambda *a: (T.reward_score(cfg, list(a[:-1]), a[-1]),),
         (*p_specs, tok))

    emit("value",
         lambda *a: (M.forward_value(cfg, list(a[:-1]), a[-1]),),
         (*p_specs, tok))

    n_p = len(shapes)

    def grpo_step(*a):
        params = list(a[:n_p])
        m = list(a[n_p:2 * n_p])
        v = list(a[2 * n_p:3 * n_p])
        step, tokens, logp_old, logp_ref, adv, mask = a[3 * n_p:]
        new_p, new_m, new_v, loss, kl = T.grpo_train_step(
            cfg, params, m, v, step, tokens, logp_old, logp_ref, adv, mask,
            lr=lr, clip_eps=clip_eps, kl_beta=kl_beta)
        return (*new_p, *new_m, *new_v, loss, kl)

    emit("grpo_train", grpo_step,
         (*p_specs, *p_specs, *p_specs, step_s, tok, seq1, seq1, advs, seq1))

    def critic_step(*a):
        params = list(a[:n_p])
        m = list(a[n_p:2 * n_p])
        v = list(a[2 * n_p:3 * n_p])
        step, tokens, returns, mask = a[3 * n_p:]
        new_p, new_m, new_v, loss = T.ppo_critic_train_step(
            cfg, params, m, v, step, tokens, returns, mask, lr=lr)
        return (*new_p, *new_m, *new_v, loss)

    emit("critic_train", critic_step,
         (*p_specs, *p_specs, *p_specs, step_s, tok, seq1, seq1))

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "n_layers": cfg.n_layers,
            "max_len": cfg.max_len,
        },
        "batch": batch,
        "hyper": {"lr": lr, "clip_eps": clip_eps, "kl_beta": kl_beta},
        "n_params": n_p,
        "param_names": param_names(cfg),
        "param_shapes": [list(s) for s in shapes],
        "entrypoints": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


PRESETS = {
    # ~1.1M params — the CPU-interpret substrate budget (DESIGN.md §2).
    "tiny": ModelCfg(vocab=64, d_model=128, n_heads=4, d_ff=512,
                     n_layers=4, max_len=96),
    # ~5M params — slower, for longer runs.
    "small": ModelCfg(vocab=64, d_model=256, n_heads=8, d_ff=1024,
                      n_layers=6, max_len=128),
}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--clip-eps", type=float, default=0.2)
    ap.add_argument("--kl-beta", type=float, default=0.04)
    args = ap.parse_args(argv)
    cfg = PRESETS[args.preset]
    manifest = build(cfg, args.batch, args.out_dir, args.lr, args.clip_eps,
                     args.kl_beta)
    total = sum(
        int(jnp.prod(jnp.array(s))) for s in manifest["param_shapes"])
    print(f"wrote {len(manifest['entrypoints'])} entry points to "
          f"{args.out_dir} ({total/1e6:.2f}M params)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
