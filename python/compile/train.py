"""GRPO / PPO training step: loss (via the fused Pallas token-loss
kernel), jax.grad, and an Adam update — one pure function per algorithm,
AOT-lowered by aot.py and executed from rust.

State layout (flat lists, mirroring `model.param_names`):
    params, adam_m, adam_v  — one array per parameter.
"""

import functools
from typing import List

import jax
import jax.numpy as jnp

from .kernels.fused_loss import grpo_token_loss
from .model import ModelCfg, forward_logits, forward_value, token_logprobs


@functools.partial(jax.jit, static_argnums=0)
def _noop(cfg):  # pragma: no cover - placeholder to keep jit imported
    return None


def grpo_loss(cfg: ModelCfg, params: List[jnp.ndarray], tokens, logp_old,
              logp_ref, adv, mask, clip_eps=0.2, kl_beta=0.04):
    """Masked-mean GRPO loss over response tokens.

    Args:
        tokens:   ``[B, L]`` int32 prompt+response.
        logp_old: ``[B, L-1]`` behaviour-policy log-probs.
        logp_ref: ``[B, L-1]`` reference-policy log-probs.
        adv:      ``[B]`` group-normalized advantages.
        mask:     ``[B, L-1]`` float, 1 on response positions.
    """
    logp_new = token_logprobs(cfg, params, tokens)        # [B, L-1]
    adv2d = jnp.broadcast_to(adv[:, None], logp_new.shape)
    tok = grpo_token_loss(logp_new, logp_old, logp_ref, adv2d, mask,
                          clip_eps, kl_beta)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = tok.sum() / denom
    # Diagnostics: mean KL over response tokens.
    delta = logp_ref - logp_new
    kl = ((jnp.exp(delta) - delta - 1.0) * mask).sum() / denom
    return loss, kl


def adam_update(params, grads, m, v, step, lr=3e-4, b1=0.9, b2=0.999,
                eps=1e-8):
    """One Adam step over flat lists. `step` is the 1-based step count."""
    new_p, new_m, new_v = [], [], []
    t = step.astype(jnp.float32)
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        m_hat = mi / (1 - b1 ** t)
        v_hat = vi / (1 - b2 ** t)
        new_p.append(p - lr * m_hat / (jnp.sqrt(v_hat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def grpo_train_step(cfg: ModelCfg, params, m, v, step, tokens, logp_old,
                    logp_ref, adv, mask, lr=3e-4, clip_eps=0.2,
                    kl_beta=0.04):
    """Full GRPO update; returns (new_params, new_m, new_v, loss, kl)."""
    (loss, kl), grads = jax.value_and_grad(
        lambda p: grpo_loss(cfg, p, tokens, logp_old, logp_ref, adv, mask,
                            clip_eps, kl_beta), has_aux=True)(params)
    new_p, new_m, new_v = adam_update(params, grads, m, v, step, lr=lr)
    return new_p, new_m, new_v, loss, kl


def ppo_critic_loss(cfg: ModelCfg, params, tokens, returns, mask):
    """MSE value loss over response tokens (PPO critic)."""
    values = forward_value(cfg, params, tokens)[:, :-1]   # align with mask
    err = (values - returns) * mask
    return (err * err).sum() / jnp.maximum(mask.sum(), 1.0)


def ppo_critic_train_step(cfg: ModelCfg, params, m, v, step, tokens,
                          returns, mask, lr=3e-4):
    """Critic update; returns (new_params, new_m, new_v, loss)."""
    loss, grads = jax.value_and_grad(
        lambda p: ppo_critic_loss(cfg, p, tokens, returns, mask))(params)
    new_p, new_m, new_v = adam_update(params, grads, m, v, step, lr=lr)
    return new_p, new_m, new_v, loss


def reward_score(cfg: ModelCfg, params, tokens):
    """Scalar score per sequence from the value head at the last position
    (a learned reward model; the arithmetic tasks also have a rule-based
    verifier on the rust side)."""
    return forward_value(cfg, params, tokens)[:, -1]


__all__ = [
    "ModelCfg", "grpo_loss", "grpo_train_step", "adam_update",
    "ppo_critic_loss", "ppo_critic_train_step", "reward_score",
    "forward_logits",
]
