//! Quickstart: schedule a GRPO job for Qwen-8B on the 64-GPU
//! Multi-Country testbed with the hybrid SHA-EA scheduler, apply load
//! balancing, compare against the verl baseline, and check the plan on
//! the discrete-event simulator.
//!
//! Run: `cargo run --release --example quickstart`

use hetrl::balance::{self, BalanceConfig};
use hetrl::costmodel::CostModel;
use hetrl::scheduler::{Budget, Scheduler, ShaEaScheduler, VerlScheduler};
use hetrl::simulator::{simulate_plan, SimConfig};
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::units::fmt_secs;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

fn main() {
    hetrl::util::logging::init();
    let topo = build_testbed(Scenario::MultiCountry, &TestbedSpec::default());
    let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_8b());
    let job = JobConfig::default();
    println!(
        "== HetRL quickstart: {} · {} · {} GPUs across {} regions ==\n",
        wf.name(),
        wf.tasks[0].model.name,
        topo.n(),
        topo.region_names.len()
    );

    // 1. HetRL (SHA-EA) search.
    let mut hetrl = ShaEaScheduler::new(42);
    let out = hetrl.schedule(&topo, &wf, &job, Budget::timed(800, 120.0));
    let plan = out.plan.expect("SHA-EA found no plan");
    println!(
        "HetRL(SHA-EA): {} cost-model evals in {} → predicted iter {}",
        out.evals,
        fmt_secs(out.wall),
        fmt_secs(out.cost)
    );
    print!("{}", plan.describe(&wf, &topo));

    // 2. Load balancing on top.
    let balanced = balance::apply(&plan, &wf, &topo, BalanceConfig::default());
    let cm = CostModel::new(&topo, &wf, &job);
    let before = cm.plan_cost(&plan).iter_time;
    let after = cm.plan_cost(&balanced).iter_time;
    println!(
        "\nload balancing: {} → {} ({:+.1}%)",
        fmt_secs(before),
        fmt_secs(after),
        (after / before - 1.0) * 100.0
    );

    // 3. verl baseline on the same fleet.
    let mut verl = VerlScheduler::new(42);
    let vout = verl.schedule(&topo, &wf, &job, Budget::timed(200, 60.0));
    println!(
        "verl baseline: predicted iter {} → HetRL speedup {:.2}x",
        fmt_secs(vout.cost),
        vout.cost / after
    );

    // 4. Discrete-event simulation of the balanced plan.
    let sim = simulate_plan(&topo, &wf, &job, &balanced, &SimConfig::default());
    println!(
        "\nsimulated: iter {} ± {} | {:.1} samples/s | device util {:.0}%",
        fmt_secs(sim.iter_time),
        fmt_secs(sim.iter_std),
        sim.throughput,
        sim.utilization * 100.0
    );
    println!(
        "cost-model prediction error vs simulator: {:.1}%",
        hetrl::util::stats::rel_err(after, sim.iter_time) * 100.0
    );
}
