//! Exact ILP scheduling on the paper's small-scale setting (§5.4 /
//! Figure 6): ≤ 24 GPUs, where HetRL(ILP) finds optimal plans in
//! minutes and HetRL(SHA-EA) lands within ~1%.
//!
//! Run: `cargo run --release --example ilp_exact`

use hetrl::scheduler::{Budget, IlpScheduler, Scheduler, ShaEaScheduler};
use hetrl::topology::{build_testbed, subset_by_model, GpuModel, Scenario, TestbedSpec};
use hetrl::util::units::fmt_secs;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};
use std::time::Instant;

fn main() {
    hetrl::util::logging::init();
    let full = build_testbed(Scenario::SingleRegion, &TestbedSpec::default());
    let topo = subset_by_model(
        &full,
        &[(GpuModel::A100, 8), (GpuModel::L40S, 8), (GpuModel::L4, 8)],
    );
    let wf = RlWorkflow::new(Algo::Grpo, Mode::Sync, ModelSpec::qwen_4b());
    let job = JobConfig::default();
    println!(
        "small-scale exact scheduling: {} GPUs (8×A100 + 8×L40S + 8×L4), {}\n",
        topo.n(),
        wf.name()
    );

    let t0 = Instant::now();
    let mut ilp = IlpScheduler::with_time_limit(120.0);
    let iout = ilp.schedule(&topo, &wf, &job, Budget::timed(1_000_000, 180.0));
    println!(
        "HetRL(ILP):    predicted iter {} found in {}",
        fmt_secs(iout.cost),
        fmt_secs(t0.elapsed().as_secs_f64())
    );
    if let Some(plan) = &iout.plan {
        print!("{}", plan.describe(&wf, &topo));
    }

    let t1 = Instant::now();
    let mut sha = ShaEaScheduler::new(9);
    let sout = sha.schedule(&topo, &wf, &job, Budget::timed(1_200, 120.0));
    println!(
        "\nHetRL(SHA-EA): predicted iter {} found in {} ({} evals)",
        fmt_secs(sout.cost),
        fmt_secs(t1.elapsed().as_secs_f64()),
        sout.evals
    );
    let gap = (sout.cost / iout.cost - 1.0) * 100.0;
    println!("SHA-EA vs ILP gap: {gap:+.2}% (paper reports within 1%)");
}
