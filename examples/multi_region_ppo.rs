//! Scenario sweep: PPO-Sync for Qwen-4B scheduled by HetRL, verl and
//! StreamRL across the four network scenarios (paper §5.1), with the
//! simulator as ground truth. Prints the Figure-3-style rows for one
//! model size.
//!
//! Run: `cargo run --release --example multi_region_ppo`

use hetrl::balance::{self, BalanceConfig};
use hetrl::scheduler::{
    Budget, Scheduler, ShaEaScheduler, StreamRlScheduler, VerlScheduler,
};
use hetrl::simulator::{simulate_plan, SimConfig};
use hetrl::topology::{build_testbed, Scenario, TestbedSpec};
use hetrl::util::table::Table;
use hetrl::workflow::{Algo, JobConfig, Mode, ModelSpec, RlWorkflow};

fn main() {
    hetrl::util::logging::init();
    let job = JobConfig::default();
    let model = ModelSpec::qwen_4b();
    let mut table = Table::new(
        "PPO-Sync · Qwen-4B · 64 GPUs: simulated throughput (samples/s)",
        &["scenario", "HetRL", "verl", "StreamRL", "HetRL/verl"],
    );
    for scenario in Scenario::ALL {
        let topo = build_testbed(scenario, &TestbedSpec::default());
        let wf = RlWorkflow::new(Algo::Ppo, Mode::Sync, model.clone());
        let sim_cfg = SimConfig { iters: 2, ..SimConfig::default() };

        let mut throughput = |mut s: Box<dyn Scheduler>, budget: usize| -> f64 {
            let out = s.schedule(&topo, &wf, &job, Budget::timed(budget, 90.0));
            match out.plan {
                Some(plan) => {
                    let plan = balance::apply(&plan, &wf, &topo, BalanceConfig::default());
                    simulate_plan(&topo, &wf, &job, &plan, &sim_cfg).throughput
                }
                None => 0.0,
            }
        };
        let hetrl = throughput(Box::new(ShaEaScheduler::new(1)), 600);
        let verl = throughput(Box::new(VerlScheduler::new(1)), 150);
        let streamrl = throughput(Box::new(StreamRlScheduler::new(1)), 200);
        table.row(vec![
            scenario.name().to_string(),
            format!("{hetrl:.1}"),
            format!("{verl:.1}"),
            format!("{streamrl:.1}"),
            format!("{:.2}x", hetrl / verl.max(1e-9)),
        ]);
        eprintln!("{} done", scenario.name());
    }
    table.print();
}
