//! End-to-end driver (DESIGN.md §5): real GRPO training of the
//! AOT-compiled transformer on synthetic arithmetic, through the full
//! stack — rust coordinator → PJRT runtime → JAX/Pallas artifacts —
//! with Python never on the request path.
//!
//! Logs the reward/loss curve, evaluates greedy accuracy, and writes
//! `results/train_grpo_curve.json`. Recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example train_grpo -- [steps]`

use hetrl::engine::{GrpoConfig, GrpoTrainer, TaskDifficulty, WorkerFleet};
use hetrl::metrics::RunRecord;
use hetrl::runtime::Runtime;
use hetrl::util::json::Json;
use hetrl::util::units::fmt_secs;

fn main() -> hetrl::util::error::Result<()> {
    hetrl::util::logging::init();
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let rt = Runtime::load("artifacts")?;
    println!(
        "runtime: {} | {:.2}M params | batch {} | maxlen {}",
        rt.platform(),
        rt.manifest.total_params() as f64 / 1e6,
        rt.manifest.batch,
        rt.model().max_len
    );

    let cfg = GrpoConfig {
        group_size: 4,
        max_new: 12,
        temperature: 1.0,
        difficulty: TaskDifficulty::Easy,
        seed: 7,
        expert_inject: true,
    };
    let fleet = WorkerFleet::heterogeneous_default();
    println!(
        "fleet: {} workers, aggregate throughput {:.2}x reference\n",
        fleet.n_workers(),
        fleet.throughput()
    );
    let mut trainer = GrpoTrainer::new(&rt, cfg, fleet)?;

    let acc0 = trainer.evaluate(2)?;
    println!("initial greedy accuracy: {:.1}%", acc0 * 100.0);

    let mut record = RunRecord::new(
        "train_grpo_curve",
        &["step", "reward", "loss", "kl", "wall_s", "virtual_wall_s"],
    );
    let t0 = std::time::Instant::now();
    let mut reward_ema = 0.0f64;
    for s in 0..steps {
        let st = trainer.step()?;
        reward_ema = if s == 0 {
            st.mean_reward
        } else {
            0.9 * reward_ema + 0.1 * st.mean_reward
        };
        record.push(vec![
            Json::num(st.step as f64),
            Json::num(st.mean_reward),
            Json::num(st.loss),
            Json::num(st.kl),
            Json::num(t0.elapsed().as_secs_f64()),
            Json::num(st.virtual_wall),
        ]);
        if s % 10 == 0 || s + 1 == steps {
            println!(
                "step {:>4} | reward {:.3} (ema {:.3}) | loss {:+.4} | kl {:.4} | {}/step",
                st.step,
                st.mean_reward,
                reward_ema,
                st.loss,
                st.kl,
                fmt_secs(st.wall)
            );
        }
    }
    let acc1 = trainer.evaluate(4)?;
    println!(
        "\nfinal greedy accuracy: {:.1}% (from {:.1}%) after {} steps in {}",
        acc1 * 100.0,
        acc0 * 100.0,
        steps,
        fmt_secs(t0.elapsed().as_secs_f64())
    );
    let path = record.save(&hetrl::metrics::results_dir())?;
    println!("curve written to {}", path.display());
    Ok(())
}
